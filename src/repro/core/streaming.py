"""Streaming / merge-reduce coresets (paper §1.1 "merge and reduce").

Coresets of disjoint sub-signals *compose*: if (C_i, u_i) is a (k, eps)-
coreset of row-band D_i, the union is a (k, eps)-coreset of D = U D_i — a
k-segmentation restricted to a band is still a <=k-segmentation, and the
per-band multiplicative errors add up to eps * ell(D, s).  ``compose`` is
therefore exact concatenation (with row offsets).

``recompress`` runs the full pipeline again over the *weighted* union
(coreset points rastered to per-cell moments), giving the classic
merge-reduce tree: eps grows additively per level, size stays bounded.  It
is a dispatched op (``repro.ops.streaming_compress``): the integral images
of the moment rasters — the compute-heavy stage — run on the numpy f64
oracle, the jitted xla path, or the sat2d Pallas kernel, and MANY buckets
recompress in one batched dispatch.

``StreamingBuilder`` maintains the log-depth bucket structure for a stream
of row bands and supports *band replacement* (dynamic updates, challenge
(iv) of the paper's introduction): the per-band leaf coresets are retained,
a replaced band rebuilds only its leaf (O(band)) and marks the one bucket
containing it dirty; ``flush_dirty`` replays just those buckets' merge
cascades, recompressing all buckets of a tree level through a single
``streaming_compress`` dispatch.  Memory is O(#bands * coreset size) — the
tiny leaves are the price of O(band) updates instead of O(N) rebuilds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .balanced import balanced_partition
from .bicriteria import bicriteria
from .caratheodory import block_representatives
from .coreset import SignalCoreset
from .stats import PrefixStats

__all__ = ["compose", "recompress", "weighted_signal_coreset", "StreamingBuilder"]


def compose(coresets: list[SignalCoreset], row_offsets: list[int], n_total: int,
            ) -> SignalCoreset:
    """Union of per-band coresets -> coreset of the stacked signal."""
    if not coresets:
        raise ValueError("need at least one coreset")
    m = coresets[0].m
    rects = []
    for cs, off in zip(coresets, row_offsets):
        r = cs.rects.copy()
        r[:, 0] += off
        r[:, 1] += off
        rects.append(r)
    return SignalCoreset(
        n=n_total, m=m, k=coresets[0].k,
        eps=max(c.eps for c in coresets),
        rects=np.concatenate(rects, axis=0),
        labels=np.concatenate([c.labels for c in coresets], axis=0),
        weights=np.concatenate([c.weights for c in coresets], axis=0),
        moments=np.concatenate([c.moments for c in coresets], axis=0),
        sigma=min(c.sigma for c in coresets),
        tolerance=min(c.tolerance for c in coresets),
        max_slices=max(c.max_slices for c in coresets),
        bicriteria=coresets[0].bicriteria,
        build_seconds=sum(c.build_seconds for c in coresets),
        certified=all(c.certified for c in coresets),
    )


# ------------------------------------------------- weighted re-compression
@dataclasses.dataclass
class _Prep:
    """Rasterized point set of one coreset awaiting re-compression: the
    host-side half of ``streaming_compress`` shared by every backend (the
    backends only differ in how ``rasters`` become integral images)."""

    rows: np.ndarray
    cols: np.ndarray
    labels: np.ndarray
    weights: np.ndarray
    rasters: tuple  # (w0, w1, w2) per-cell (sum w, sum w*y, sum w*y^2)


def _raster_moments(n: int, m: int, rows, cols, labels, weights):
    w0 = np.zeros((n, m), np.float64)
    w1 = np.zeros((n, m), np.float64)
    w2 = np.zeros((n, m), np.float64)
    np.add.at(w0, (rows, cols), weights)
    np.add.at(w1, (rows, cols), weights * labels)
    np.add.at(w2, (rows, cols), weights * labels * labels)
    return w0, w1, w2


def _recompress_prep(cs: SignalCoreset) -> _Prep:
    # exact-moment (Caratheodory) labels: re-compression must preserve M2
    X, y, w = cs.as_points(style="caratheodory")
    rows = X[:, 0].astype(np.int64)
    cols = X[:, 1].astype(np.int64)
    return _Prep(rows, cols, y, w,
                 _raster_moments(cs.n, cs.m, rows, cols, y, w))


def _recompress_finish(cs: SignalCoreset, prep: _Prep, ps: PrefixStats,
                       k: int | None, eps: float | None) -> SignalCoreset:
    return weighted_signal_coreset(
        cs.n, cs.m, prep.rows, prep.cols, prep.labels, prep.weights,
        k or cs.k, eps or cs.eps, _moments=prep.rasters, _stats=ps)


def weighted_signal_coreset(n: int, m: int, rows: np.ndarray, cols: np.ndarray,
                            labels: np.ndarray, weights: np.ndarray, k: int,
                            eps: float, *, fidelity: str = "practical",
                            tolerance_override: float | None = None,
                            max_slices_override: int | None = None,
                            _sigma_hint=None, _moments=None,
                            _stats: PrefixStats | None = None) -> SignalCoreset:
    """SIGNAL-CORESET over a weighted sparse signal (points on the grid).

    Used by merge-reduce: the input points are themselves coreset points.
    All pipeline stages only consume (sum w, sum w y, sum w y^2) rasters, so
    the generalization is direct.  ``_moments``/``_stats`` (the rasters and
    their integral images) let the ``streaming_compress`` backends supply
    precomputed/batched stats instead of rebuilding them here.
    """
    import time
    t0 = time.perf_counter()
    rows = np.asarray(rows, np.int64); cols = np.asarray(cols, np.int64)
    labels = np.asarray(labels, np.float64); weights = np.asarray(weights, np.float64)
    if _moments is None:
        w0, w1, w2 = _raster_moments(n, m, rows, cols, labels, weights)
    else:
        w0, w1, w2 = _moments

    ps = PrefixStats.build_moments(w0, w1, w2) if _stats is None else _stats
    if _sigma_hint is not None:       # size-bisection path: sigma known
        sigma, certified, bic = _sigma_hint
    else:
        bic = bicriteria(None, k, fidelity=fidelity, moments=(w0, w1, w2))
        sigma = bic.sigma
        certified = True
        if fidelity != "paper":
            # heuristic sigma floor (see signal_coreset): greedy k-tree loss/4
            from .segmentation import greedy_tree
            g = greedy_tree(ps, k)
            s0, s1, s2 = ps.sums(g.rects[:, 0], g.rects[:, 1], g.rects[:, 2], g.rects[:, 3])
            heur = float(np.maximum(s2 - s1 * s1 / np.maximum(s0, 1e-300), 0.0).sum()) / 6.0
            if heur > sigma:
                sigma, certified = heur, False
    from .coreset import resolve_partition_params
    tol, max_slices = resolve_partition_params(sigma, k, eps, fidelity, bic.alpha_hat)
    if tolerance_override is not None:
        tol = float(tolerance_override)
    if max_slices_override is not None:
        max_slices = int(max_slices_override)

    part = balanced_partition(ps, tol, max_slices)
    raster = part.block_id_raster(n, m)
    bid_pts = raster[rows, cols]
    lab4, w4, mom = block_representatives(labels, bid_pts, part.num_blocks,
                                          w_flat=weights)
    keep = mom[:, 0] > 0  # drop mass-less blocks (all-empty regions)
    return SignalCoreset(
        n=n, m=m, k=k, eps=eps,
        rects=part.rects[keep], labels=lab4[keep], weights=w4[keep],
        moments=mom[keep], sigma=float(sigma), tolerance=tol,
        max_slices=max_slices, bicriteria=bic,
        build_seconds=time.perf_counter() - t0, certified=certified,
    )


def recompress(cs: SignalCoreset, k: int | None = None, eps: float | None = None,
               *, backend: str | None = None) -> SignalCoreset:
    """Reduce step of merge-reduce: coreset-of-the-coreset (dispatched)."""
    from repro import ops
    return ops.streaming_compress([cs], k, eps, backend=backend)[0]


# --------------------------------------------------------- streaming builder
@dataclasses.dataclass
class _Leaf:
    """One ingested band: its coreset plus absolute row placement."""

    cs: SignalCoreset
    row0: int
    rows: int

    @property
    def item(self) -> tuple:
        return (self.cs, self.row0, self.rows)


@dataclasses.dataclass
class _Bucket:
    """A binary-counter bucket: the merged coreset of ``count`` (= 2^level)
    contiguous bands starting at band index ``start``.  ``dirty`` marks a
    bucket whose constituent leaf changed and whose cascade must replay."""

    level: int
    start: int
    count: int
    item: tuple      # (coreset, absolute row0, rows)
    dirty: bool = False


@dataclasses.dataclass
class StreamingBuilder:
    """Merge-reduce over a stream of row bands with dynamic band updates.

    Buckets hold coresets of 2^level bands; inserting a band cascades merges
    (compose + recompress) like binary addition, so each band is touched
    O(log #bands) times.  The per-band *leaf* coresets are retained so that
    ``replace_band`` costs O(band): the replaced leaf rebuilds, the single
    bucket containing it is marked dirty, and ``flush_dirty`` (called by
    ``result``) replays only the dirty buckets' merge cascades — every
    recompression of a cascade level runs in ONE batched
    ``repro.ops.streaming_compress`` dispatch.
    """

    m: int
    k: int
    eps: float
    recompress_levels: bool = True
    _leaves: list = dataclasses.field(default_factory=list)
    _buckets: dict[int, _Bucket] = dataclasses.field(default_factory=dict)
    _next_row: int = 0
    buckets_recompressed_total: int = 0   # lifetime flush_dirty recompressions

    def _merge(self, a: tuple, b: tuple, *, recompress_now: bool = True) -> tuple:
        lo = min(a[1], b[1])
        total = a[2] + b[2]
        merged = compose([a[0], b[0]], [a[1] - lo, b[1] - lo], n_total=total)
        if self.recompress_levels and recompress_now:
            merged = recompress(merged)
        return (merged, lo, total)

    def insert_band(self, band_values: np.ndarray, *, _leaf_cs=None) -> None:
        from .coreset import signal_coreset
        # settle pending replacements first: the cascade below merges bucket
        # items, and merging a dirty bucket's stale item would bake the old
        # leaf into a clean higher-level bucket no flush could ever repair
        self.flush_dirty()
        band_values = np.asarray(band_values, np.float64)
        # _leaf_cs (internal): prebuilt signal_coreset(band, k, eps) of this
        # band — the serving engine's delta fast path builds the leaf once
        # per (k, eps) spec and shares it between the cache splice and every
        # live builder, instead of rebuilding it here per consumer
        cs = (_leaf_cs if _leaf_cs is not None
              else signal_coreset(band_values, self.k, self.eps))
        leaf = _Leaf(cs, self._next_row, band_values.shape[0])
        self._leaves.append(leaf)
        self._next_row += leaf.rows
        item = leaf.item
        level, start, count = 0, len(self._leaves) - 1, 1
        while level in self._buckets:
            other = self._buckets.pop(level)
            item = self._merge(other.item, item)
            start, count = other.start, other.count + count
            level += 1
        self._buckets[level] = _Bucket(level, start, count, item)

    # ------------------------------------------------------- dynamic updates
    @property
    def num_bands(self) -> int:
        return len(self._leaves)

    def band_range(self, index: int) -> tuple[int, int]:
        """(row0, rows) of ingested band ``index``."""
        leaf = self._leaves[index]
        return leaf.row0, leaf.rows

    def _bucket_of(self, index: int) -> _Bucket:
        for bucket in self._buckets.values():
            if bucket.start <= index < bucket.start + bucket.count:
                return bucket
        raise ValueError(f"band index {index} not covered by any bucket")

    def replace_band(self, index: int, band_values: np.ndarray, *,
                     _leaf_cs=None) -> None:
        """Replace ingested band ``index`` with same-shape values: O(band)
        leaf rebuild now, a dirty mark on the one bucket containing it;
        recompression is deferred to ``flush_dirty`` so a burst of updates
        amortizes into one batched dispatch.

        ``_leaf_cs`` (internal) injects a prebuilt ``signal_coreset(band,
        k, eps)`` of the new content — the serving engine fans a delta
        burst's leaf builds out over its scheduler pool and hands each
        builder its finished leaf, so N replaced bands cost one batched
        submission instead of N sequential builds here.
        """
        from .coreset import signal_coreset
        band_values = np.asarray(band_values, np.float64)
        leaf = self._leaves[index]
        if band_values.shape != (leaf.rows, self.m):
            raise ValueError(
                f"replacement band must have shape ({leaf.rows}, {self.m}), "
                f"got {band_values.shape}")
        leaf.cs = (_leaf_cs if _leaf_cs is not None
                   else signal_coreset(band_values, self.k, self.eps))
        bucket = self._bucket_of(index)
        if bucket.count == 1:
            bucket.item = leaf.item    # a leaf bucket IS its band coreset
            bucket.dirty = False
        else:
            bucket.dirty = True

    @property
    def dirty_buckets(self) -> int:
        return sum(1 for b in self._buckets.values() if b.dirty)

    def flush_dirty(self) -> int:
        """Replay the merge cascade of every dirty bucket; returns the
        number of bucket recompressions performed.  The replay is level-
        synchronized across buckets: all compositions of one cascade level
        recompress in a single ``streaming_compress`` dispatch, and the
        pairwise left-to-right tree is exactly the shape the insert cascade
        built, so a flushed bucket is bitwise identical to a from-scratch
        rebuild of the same bands.
        """
        dirty = [b for b in self._buckets.values() if b.dirty]
        if not dirty:
            return 0
        pend = {id(b): [leaf.item
                        for leaf in self._leaves[b.start:b.start + b.count]]
                for b in dirty}
        done = 0
        while any(len(items) > 1 for items in pend.values()):
            staged = []   # (bucket id, position, composed item)
            for key, items in pend.items():
                if len(items) == 1:
                    continue
                merged_level = []
                for i in range(0, len(items), 2):   # counts are powers of 2
                    merged_level.append(
                        self._merge(items[i], items[i + 1],
                                    recompress_now=False))
                    staged.append((key, len(merged_level) - 1,
                                   merged_level[-1]))
                pend[key] = merged_level
            if self.recompress_levels and staged:
                from repro import ops
                rcs = ops.streaming_compress([it[0] for _, _, it in staged])
                done += len(staged)
                for (key, pos, item), cs in zip(staged, rcs):
                    pend[key][pos] = (cs, item[1], item[2])
        for b in dirty:
            b.item = pend[id(b)][0]
            b.dirty = False
        self.buckets_recompressed_total += done
        return done

    # --------------------------------------------------------------- results
    @property
    def max_level(self) -> int:
        """Deepest occupied bucket = number of recompress layers any band may
        have passed through (eps composes as (1+eps)^(max_level+1) - 1)."""
        return max(self._buckets, default=0)

    @property
    def rows_seen(self) -> int:
        return self._next_row

    def result(self) -> SignalCoreset:
        self.flush_dirty()
        items = sorted((b.item for b in self._buckets.values()),
                       key=lambda t: t[1])
        if not items:
            raise ValueError("empty stream")
        return compose([it[0] for it in items], [it[1] for it in items],
                       n_total=self._next_row)
