"""Streaming / merge-reduce coresets (paper §1.1 "merge and reduce").

Coresets of disjoint sub-signals *compose*: if (C_i, u_i) is a (k, eps)-
coreset of row-band D_i, the union is a (k, eps)-coreset of D = U D_i — a
k-segmentation restricted to a band is still a <=k-segmentation, and the
per-band multiplicative errors add up to eps * ell(D, s).  ``compose`` is
therefore exact concatenation (with row offsets).

``recompress`` runs the full pipeline again over the *weighted* union
(coreset points rastered to per-cell moments), giving the classic
merge-reduce tree: eps grows additively per level, size stays bounded.
``StreamingBuilder`` maintains the log-depth bucket structure for an
append-only stream of row bands, and supports band replacement (dynamic
updates, challenge (iv) of the paper's introduction).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .balanced import balanced_partition
from .bicriteria import bicriteria
from .caratheodory import block_representatives
from .coreset import SignalCoreset
from .stats import PrefixStats

__all__ = ["compose", "recompress", "weighted_signal_coreset", "StreamingBuilder"]


def compose(coresets: list[SignalCoreset], row_offsets: list[int], n_total: int,
            ) -> SignalCoreset:
    """Union of per-band coresets -> coreset of the stacked signal."""
    if not coresets:
        raise ValueError("need at least one coreset")
    m = coresets[0].m
    rects = []
    for cs, off in zip(coresets, row_offsets):
        r = cs.rects.copy()
        r[:, 0] += off
        r[:, 1] += off
        rects.append(r)
    return SignalCoreset(
        n=n_total, m=m, k=coresets[0].k,
        eps=max(c.eps for c in coresets),
        rects=np.concatenate(rects, axis=0),
        labels=np.concatenate([c.labels for c in coresets], axis=0),
        weights=np.concatenate([c.weights for c in coresets], axis=0),
        moments=np.concatenate([c.moments for c in coresets], axis=0),
        sigma=min(c.sigma for c in coresets),
        tolerance=min(c.tolerance for c in coresets),
        max_slices=max(c.max_slices for c in coresets),
        bicriteria=coresets[0].bicriteria,
        build_seconds=sum(c.build_seconds for c in coresets),
        certified=all(c.certified for c in coresets),
    )


def weighted_signal_coreset(n: int, m: int, rows: np.ndarray, cols: np.ndarray,
                            labels: np.ndarray, weights: np.ndarray, k: int,
                            eps: float, *, fidelity: str = "practical",
                            tolerance_override: float | None = None,
                            max_slices_override: int | None = None,
                            _sigma_hint=None) -> SignalCoreset:
    """SIGNAL-CORESET over a weighted sparse signal (points on the grid).

    Used by merge-reduce: the input points are themselves coreset points.
    All pipeline stages only consume (sum w, sum w y, sum w y^2) rasters, so
    the generalization is direct.
    """
    import time
    t0 = time.perf_counter()
    rows = np.asarray(rows, np.int64); cols = np.asarray(cols, np.int64)
    labels = np.asarray(labels, np.float64); weights = np.asarray(weights, np.float64)
    w0 = np.zeros((n, m), np.float64)
    w1 = np.zeros((n, m), np.float64)
    w2 = np.zeros((n, m), np.float64)
    np.add.at(w0, (rows, cols), weights)
    np.add.at(w1, (rows, cols), weights * labels)
    np.add.at(w2, (rows, cols), weights * labels * labels)

    ps = PrefixStats.build_moments(w0, w1, w2)
    if _sigma_hint is not None:       # size-bisection path: sigma known
        sigma, certified, bic = _sigma_hint
    else:
        bic = bicriteria(None, k, fidelity=fidelity, moments=(w0, w1, w2))
        sigma = bic.sigma
        certified = True
        if fidelity != "paper":
            # heuristic sigma floor (see signal_coreset): greedy k-tree loss/4
            from .segmentation import greedy_tree
            g = greedy_tree(ps, k)
            s0, s1, s2 = ps.sums(g.rects[:, 0], g.rects[:, 1], g.rects[:, 2], g.rects[:, 3])
            heur = float(np.maximum(s2 - s1 * s1 / np.maximum(s0, 1e-300), 0.0).sum()) / 6.0
            if heur > sigma:
                sigma, certified = heur, False
    from .coreset import resolve_partition_params
    tol, max_slices = resolve_partition_params(sigma, k, eps, fidelity, bic.alpha_hat)
    if tolerance_override is not None:
        tol = float(tolerance_override)
    if max_slices_override is not None:
        max_slices = int(max_slices_override)

    part = balanced_partition(ps, tol, max_slices)
    raster = part.block_id_raster(n, m)
    bid_pts = raster[rows, cols]
    lab4, w4, mom = block_representatives(labels, bid_pts, part.num_blocks,
                                          w_flat=weights)
    keep = mom[:, 0] > 0  # drop mass-less blocks (all-empty regions)
    return SignalCoreset(
        n=n, m=m, k=k, eps=eps,
        rects=part.rects[keep], labels=lab4[keep], weights=w4[keep],
        moments=mom[keep], sigma=float(sigma), tolerance=tol,
        max_slices=max_slices, bicriteria=bic,
        build_seconds=time.perf_counter() - t0, certified=certified,
    )


def recompress(cs: SignalCoreset, k: int | None = None, eps: float | None = None,
               ) -> SignalCoreset:
    """Reduce step of merge-reduce: coreset-of-the-coreset."""
    # exact-moment (Caratheodory) labels: re-compression must preserve M2
    X, y, w = cs.as_points(style="caratheodory")
    return weighted_signal_coreset(
        cs.n, cs.m, X[:, 0].astype(np.int64), X[:, 1].astype(np.int64), y, w,
        k or cs.k, eps or cs.eps)


@dataclasses.dataclass
class StreamingBuilder:
    """Merge-reduce over an append-only stream of row bands.

    Buckets hold coresets of 2^level bands; inserting a band cascades merges
    (compose + recompress) like binary addition, so memory stays
    O(log #bands * coreset size) and each band is touched O(log) times.
    """

    m: int
    k: int
    eps: float
    recompress_levels: bool = True
    _buckets: dict[int, tuple[SignalCoreset, int, int]] = dataclasses.field(default_factory=dict)
    _next_row: int = 0

    def insert_band(self, band_values: np.ndarray) -> None:
        from .coreset import signal_coreset
        cs = signal_coreset(band_values, self.k, self.eps)
        item = (cs, self._next_row, band_values.shape[0])
        self._next_row += band_values.shape[0]
        level = 0
        while level in self._buckets:
            other, o_row, o_rows = self._buckets.pop(level)
            lo = min(o_row, item[1])
            merged = compose([other, item[0]], [o_row - lo, item[1] - lo],
                             n_total=o_rows + item[2])
            if self.recompress_levels:
                merged = recompress(merged)
            # re-anchor: merged covers rows [lo, lo + total)
            item = (merged, lo, o_rows + item[2])
            level += 1
        self._buckets[level] = item

    @property
    def max_level(self) -> int:
        """Deepest occupied bucket = number of recompress layers any band may
        have passed through (eps composes as (1+eps)^(max_level+1) - 1)."""
        return max(self._buckets, default=0)

    @property
    def rows_seen(self) -> int:
        return self._next_row

    def result(self) -> SignalCoreset:
        items = sorted(self._buckets.values(), key=lambda t: t[1])
        if not items:
            raise ValueError("empty stream")
        return compose([it[0] for it in items], [it[1] for it in items],
                       n_total=self._next_row)
