"""Algorithm 5 (FITTING-LOSS) — evaluate any k-segmentation against the coreset.

Two-phase vectorized evaluation, mirroring the paper's case analysis:

  * non-intersected blocks (z = 1 distinct value): the covering leaf's label
    lam gives the *exact* loss  M2 - 2 lam M1 + lam^2 M0  (moment matching,
    Case (i) of Claim 14.1);
  * intersected blocks: the smoothed-assignment loss.  Leaves consume the
    block's point-weight mass in leaf order; with Z = cumsum of per-leaf
    overlap counts and U = cumsum of point weights, the mass of point i
    assigned to leaf l is the overlap of the intervals [Z_{l-1}, Z_l) and
    [U_{i-1}, U_i) — a closed form for the paper's while-loop (lines 9-25),
    vectorized over (blocks x leaves x 4).  Any consistent consumption order
    yields a valid "smoothed version" (Eqs. 9-11), so Lemma 14's guarantee
    applies unchanged.

Complexity O(|B2| * k) + O(|B|), matching the paper's O(k |C|) bound with the
balanced-partition promise |B2| << |B|.
"""
from __future__ import annotations

import numpy as np

__all__ = ["fitting_loss", "true_loss", "overlap_counts"]


def overlap_counts(block_rects: np.ndarray, seg_rects: np.ndarray) -> np.ndarray:
    """(B, K) cell-count overlaps between block and leaf rectangles."""
    br = block_rects[:, None, :]
    sr = seg_rects[None, :, :]
    dr = np.clip(np.minimum(br[..., 1], sr[..., 1]) - np.maximum(br[..., 0], sr[..., 0]), 0, None)
    dc = np.clip(np.minimum(br[..., 3], sr[..., 3]) - np.maximum(br[..., 2], sr[..., 2]), 0, None)
    return (dr * dc).astype(np.float64)


def fitting_loss(coreset, seg_rects: np.ndarray, seg_labels: np.ndarray,
                 chunk: int = 8192) -> float:
    """FITTING-LOSS((C, u), s): (1 +/- eps)-approximation of ell(D, s).

    ``seg_rects`` (K, 4) half-open leaf rectangles tiling [n] x [m];
    ``seg_labels`` (K,) their values.
    """
    seg_rects = np.asarray(seg_rects, np.int64).reshape(-1, 4)
    seg_labels = np.asarray(seg_labels, np.float64).ravel()
    B = coreset.num_blocks
    rects = coreset.rects
    M0, M1, M2 = coreset.moments[:, 0], coreset.moments[:, 1], coreset.moments[:, 2]

    # Phase 1: candidate covering leaf = the leaf containing each block's
    # top-left cell; a block is non-intersected iff that leaf covers it fully.
    r0, c0 = rects[:, 0], rects[:, 2]
    cover = np.full(B, -1, np.int64)
    for s in range(0, B, chunk):
        e = min(s + chunk, B)
        inside = ((seg_rects[None, :, 0] <= r0[s:e, None]) & (r0[s:e, None] < seg_rects[None, :, 1]) &
                  (seg_rects[None, :, 2] <= c0[s:e, None]) & (c0[s:e, None] < seg_rects[None, :, 3]))
        cover[s:e] = np.argmax(inside, axis=1)
        cover[s:e][~inside.any(axis=1)] = -1
    cov_rect = seg_rects[np.maximum(cover, 0)]
    full = ((cover >= 0) &
            (cov_rect[:, 0] <= rects[:, 0]) & (rects[:, 1] <= cov_rect[:, 1]) &
            (cov_rect[:, 2] <= rects[:, 2]) & (rects[:, 3] <= cov_rect[:, 3]))

    lam = seg_labels[np.maximum(cover, 0)]
    exact = np.where(full, M2 - 2.0 * lam * M1 + lam * lam * M0, 0.0)
    loss = float(np.maximum(exact, 0.0).sum())

    # Phase 2: smoothed assignment for the intersected blocks only.
    idx = np.flatnonzero(~full)
    if idx.size:
        U = np.cumsum(coreset.weights[idx], axis=1)            # (b, 4)
        Uprev = U - coreset.weights[idx]
        lbl = coreset.labels[idx]                               # (b, 4)
        for s in range(0, idx.size, chunk):
            sl = idx[s:s + chunk]
            z = overlap_counts(rects[sl], seg_rects)            # (b, K)
            Z = np.cumsum(z, axis=1)
            Zprev = Z - z
            lo = np.maximum(Zprev[:, :, None], Uprev[s:s + chunk, None, :])
            hi = np.minimum(Z[:, :, None], U[s:s + chunk, None, :])
            consumed = np.clip(hi - lo, 0.0, None)              # (b, K, 4)
            diff = seg_labels[None, :, None] - lbl[s:s + chunk, None, :]
            loss += float((consumed * diff * diff).sum())
    return loss


def true_loss(values: np.ndarray, seg_rects: np.ndarray, seg_labels: np.ndarray,
              ps=None) -> float:
    """Exact ell(D, s) on the full signal (for tests / baselines), O(K) via SAT."""
    from .stats import PrefixStats
    if ps is None:
        ps = PrefixStats.build(np.asarray(values, np.float64))
    seg_rects = np.asarray(seg_rects, np.int64).reshape(-1, 4)
    lam = np.asarray(seg_labels, np.float64).ravel()
    s0, s1, s2 = ps.sums(seg_rects[:, 0], seg_rects[:, 1], seg_rects[:, 2], seg_rects[:, 3])
    return float(np.maximum(s2 - 2.0 * lam * s1 + lam * lam * s0, 0.0).sum())
