"""Masked prefix statistics (summed-area tables) over an n x m signal.

Every algorithm in the paper reduces to O(1) queries of the form

    (S0, S1, S2)(R) = (sum 1, sum y, sum y^2) over a rectangle R,

optionally restricted to the *live* (not yet removed) cells.  We keep three
(n+1, m+1) float64 integral images and answer rectangle / row-interval /
column-interval queries, vectorized over arrays of rectangles.

``opt1`` (the optimal 1-segmentation SSE of a sub-signal, Definition 2 with
k=1) is ``S2 - S1^2 / S0`` — the variance identity used by Lemma 12(iv) /
Eq. (1) of the paper.

The unmasked/unweighted build routes through the ``repro.ops.sat_moments``
dispatcher (numpy oracle on host, the ``repro.kernels.sat2d`` Pallas kernel
on TPU, env-overridable); this module remains the owner of the float64
query API.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PrefixStats", "opt1_from_sums"]


def opt1_from_sums(s0, s1, s2):
    """SSE of the best constant fit given moments (vectorized, safe at s0=0).

    Uses max(.., 0) to clamp the tiny negative values float cancellation can
    produce for near-constant blocks.
    """
    s0 = np.asarray(s0, dtype=np.float64)
    s1 = np.asarray(s1, dtype=np.float64)
    s2 = np.asarray(s2, dtype=np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        v = s2 - np.where(s0 > 0, (s1 * s1) / np.maximum(s0, 1e-300), 0.0)
    return np.maximum(v, 0.0)


@dataclasses.dataclass(frozen=True)
class PrefixStats:
    """Integral images of (count, y, y^2) for a (possibly masked, weighted) signal.

    ``p0/p1/p2`` have shape (n+1, m+1); entry [i, j] is the sum over the
    sub-matrix [0:i, 0:j].  Queries take half-open index ranges.
    """

    p0: np.ndarray
    p1: np.ndarray
    p2: np.ndarray

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(values: np.ndarray, mask: np.ndarray | None = None,
              weights: np.ndarray | None = None) -> "PrefixStats":
        y = np.asarray(values, dtype=np.float64)
        if y.ndim != 2:
            raise ValueError(f"signal must be 2D, got shape {y.shape}")
        n, m = y.shape
        if mask is None and weights is None:
            # the common (unmasked, unweighted) path goes through the op
            # dispatcher: numpy oracle by default on host (same float64
            # cumsums as before), the sat2d Pallas kernel on TPU or under
            # REPRO_OPS_BACKEND.  The float32 accelerator backends trade
            # precision for bandwidth; the query API stays float64.
            from repro import ops
            return PrefixStats.from_sat(
                np.asarray(ops.sat_moments(y), np.float64))
        w = np.ones_like(y) if weights is None else np.asarray(weights, np.float64)
        if mask is not None:
            w = w * np.asarray(mask, dtype=np.float64)

        def integral(a: np.ndarray) -> np.ndarray:
            out = np.zeros((n + 1, m + 1), dtype=np.float64)
            np.cumsum(a, axis=0, out=out[1:, 1:])
            np.cumsum(out[1:, 1:], axis=1, out=out[1:, 1:])
            return out

        return PrefixStats(integral(w), integral(w * y), integral(w * y * y))

    @staticmethod
    def from_sat(s: np.ndarray) -> "PrefixStats":
        """Wrap (3, n, m) inclusive integral images (one ``sat_moments`` /
        ``delta_sat`` output) into the zero-padded (n+1, m+1) query layout."""
        n, m = s.shape[1], s.shape[2]
        ps = []
        for c in range(3):
            out = np.zeros((n + 1, m + 1), dtype=np.float64)
            out[1:, 1:] = s[c]
            ps.append(out)
        return PrefixStats(*ps)

    # ------------------------------------------------------------ delta patch
    def carry_row(self, r0: int) -> np.ndarray:
        """(3, m) integral-image row just above signal row ``r0`` — the seed
        the ``delta_sat`` op continues from (zeros when r0 == 0)."""
        return np.stack([self.p0[r0, 1:], self.p1[r0, 1:], self.p2[r0, 1:]])

    def patch_rows(self, r0: int, tail: np.ndarray, *, copy: bool = False,
                   backend: str | None = None) -> "PrefixStats":
        """Patch the integral images for replaced/appended signal rows.

        ``tail`` (b, m) must hold the raw values of EVERY row from ``r0`` to
        the new end of the signal (rows below a replaced band shift their
        prefixes too); the new row count is ``r0 + b``.  Dispatches the
        ``repro.ops.delta_sat`` op — O(b * m) instead of the O(n * m)
        rebuild — and with the f64 numpy oracle the patched images are
        bitwise equal to a from-scratch :meth:`build`.

        When the row count is unchanged the patch is applied in place and
        ``self`` is returned (``copy=True`` forces fresh arrays — for
        callers whose readers may hold a reference); appends reallocate.
        """
        from repro import ops
        tail = np.asarray(tail, np.float64)
        n, m = self.shape
        if tail.ndim != 2 or tail.shape[1] != m:
            raise ValueError(f"tail must be (rows, {m}), got {tail.shape}")
        if not 0 <= r0 <= n:
            raise ValueError(f"row offset {r0} outside [0, {n}]")
        body = np.asarray(ops.delta_sat(self.carry_row(r0), tail,
                                        backend=backend), np.float64)
        n_new = r0 + tail.shape[0]
        if n_new == n and not copy:
            for c, p in enumerate((self.p0, self.p1, self.p2)):
                p[r0 + 1:, 1:] = body[c]
            return self
        ps = []
        for c, p in enumerate((self.p0, self.p1, self.p2)):
            out = np.zeros((n_new + 1, m + 1), dtype=np.float64)
            out[:r0 + 1] = p[:r0 + 1]
            out[r0 + 1:, 1:] = body[c]
            ps.append(out)
        return PrefixStats(*ps)

    def append_rows(self, band: np.ndarray, *,
                    backend: str | None = None) -> "PrefixStats":
        """Integral images of the signal with ``band`` appended at the
        bottom (a pure O(band) ``delta_sat`` continuation)."""
        return self.patch_rows(self.shape[0], band, backend=backend)

    @staticmethod
    def build_moments(w0: np.ndarray, w1: np.ndarray, w2: np.ndarray,
                      mask: np.ndarray | None = None) -> "PrefixStats":
        """Build from per-cell moment rasters (weighted/sparse signals: cells
        carry (sum w, sum w*y, sum w*y^2) — the generalization used by the
        merge-reduce re-compression, where coreset points form the input)."""
        n, m = w0.shape
        mk = np.ones((n, m), np.float64) if mask is None else np.asarray(mask, np.float64)

        def integral(a: np.ndarray) -> np.ndarray:
            out = np.zeros((n + 1, m + 1), dtype=np.float64)
            np.cumsum(a * mk, axis=0, out=out[1:, 1:])
            np.cumsum(out[1:, 1:], axis=1, out=out[1:, 1:])
            return out

        return PrefixStats(integral(np.asarray(w0, np.float64)),
                           integral(np.asarray(w1, np.float64)),
                           integral(np.asarray(w2, np.float64)))

    @staticmethod
    def from_points(n: int, m: int, rows: np.ndarray, cols: np.ndarray,
                    labels: np.ndarray, weights: np.ndarray) -> "PrefixStats":
        """Raster weighted points into per-cell moments (used by merge-reduce
        re-compression, where coreset points act as a weighted sparse signal)."""
        w0 = np.zeros((n, m), np.float64)
        w1 = np.zeros((n, m), np.float64)
        w2 = np.zeros((n, m), np.float64)
        np.add.at(w0, (rows, cols), weights)
        np.add.at(w1, (rows, cols), weights * labels)
        np.add.at(w2, (rows, cols), weights * labels * labels)

        def integral(a):
            out = np.zeros((n + 1, m + 1), dtype=np.float64)
            np.cumsum(a, axis=0, out=out[1:, 1:])
            np.cumsum(out[1:, 1:], axis=1, out=out[1:, 1:])
            return out

        return PrefixStats(integral(w0), integral(w1), integral(w2))

    # ----------------------------------------------------------------- shapes
    @property
    def shape(self) -> tuple[int, int]:
        return self.p0.shape[0] - 1, self.p0.shape[1] - 1

    def transpose(self) -> "PrefixStats":
        """Stats of the transposed signal (O(nm) once; used by the
        SLICEPARTITION recursion on B^T)."""
        # Integral images do not transpose directly; rebuild from differences.
        def cell(a):
            return a[1:, 1:] - a[:-1, 1:] - a[1:, :-1] + a[:-1, :-1]

        def integral(a):
            n, m = a.shape
            out = np.zeros((n + 1, m + 1), dtype=np.float64)
            np.cumsum(a, axis=0, out=out[1:, 1:])
            np.cumsum(out[1:, 1:], axis=1, out=out[1:, 1:])
            return out

        return PrefixStats(integral(cell(self.p0).T), integral(cell(self.p1).T),
                           integral(cell(self.p2).T))

    # ---------------------------------------------------------------- queries
    def sums(self, r0, r1, c0, c1):
        """Moments over [r0:r1, c0:c1] (half-open). All args may be arrays."""
        r0 = np.asarray(r0, np.int64); r1 = np.asarray(r1, np.int64)
        c0 = np.asarray(c0, np.int64); c1 = np.asarray(c1, np.int64)

        def q(p):
            return p[r1, c1] - p[r0, c1] - p[r1, c0] + p[r0, c0]

        return q(self.p0), q(self.p1), q(self.p2)

    def count(self, r0, r1, c0, c1):
        return self.sums(r0, r1, c0, c1)[0]

    def mean(self, r0, r1, c0, c1):
        s0, s1, _ = self.sums(r0, r1, c0, c1)
        return np.where(s0 > 0, s1 / np.maximum(s0, 1e-300), 0.0)

    def opt1(self, r0, r1, c0, c1):
        """opt_1 of the sub-signal (Definition 2, k=1): min_c sum (y-c)^2."""
        return opt1_from_sums(*self.sums(r0, r1, c0, c1))

    def opt1_scalar(self, r0: int, r1: int, c0: int, c1: int) -> float:
        """Scalar fast path for the greedy searches (no ufunc machinery):
        identical math to :meth:`opt1` for single rectangles."""
        p0, p1, p2 = self.p0, self.p1, self.p2
        s0 = p0[r1, c1] - p0[r0, c1] - p0[r1, c0] + p0[r0, c0]
        if s0 <= 0.0:
            return 0.0
        s1 = p1[r1, c1] - p1[r0, c1] - p1[r1, c0] + p1[r0, c0]
        s2 = p2[r1, c1] - p2[r0, c1] - p2[r1, c0] + p2[r0, c0]
        v = s2 - (s1 * s1) / s0
        return v if v > 0.0 else 0.0

    def total_opt1(self) -> float:
        n, m = self.shape
        return float(self.opt1(0, n, 0, m))

    # ------------------------------------------------- monotone-window search
    def max_col_extent(self, r0: int, r1: int, c0: int, sigma: float) -> int:
        """Largest c_end in (c0, m] with opt1([r0:r1, c0:c_end]) <= sigma.

        opt1 is monotone non-decreasing in the window (adding cells cannot
        shrink the best-constant SSE: opt1(A) <= SSE_A(mu_{A u B}) <=
        opt1(A u B)), so a binary search over the prefix stats replaces the
        paper's linear scan (Algorithm 1, line 10) — identical output,
        O(log m) instead of O(m) per slice.

        Returns c0 if even the single first column exceeds sigma.
        """
        m = self.shape[1]
        if self.opt1_scalar(r0, r1, c0, c0 + 1) > sigma:
            return c0
        lo, hi = c0 + 1, m  # invariant: opt1(.., c0, lo) <= sigma
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.opt1_scalar(r0, r1, c0, mid) <= sigma:
                lo = mid
            else:
                hi = mid - 1
        return lo

    def max_row_extent(self, c0: int, c1: int, r0: int, sigma: float) -> int:
        """Row-direction twin of :meth:`max_col_extent` (for B^T recursion)."""
        n = self.shape[0]
        if self.opt1_scalar(r0, r0 + 1, c0, c1) > sigma:
            return r0
        lo, hi = r0 + 1, n
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.opt1_scalar(r0, mid, c0, c1) <= sigma:
                lo = mid
            else:
                hi = mid - 1
        return lo
