"""Distributed coreset construction and evaluation on a JAX mesh.

The construction is embarrassingly parallel over row bands (coresets of
disjoint sub-signals compose exactly — see streaming.py).  On a real
cluster each host builds the coreset of the row band whose data it owns
(data never leaves the host: only the tiny coresets are gathered), which is
how the paper's challenge (iv) (parallel training of a single tree) is met.
In this single-process container the per-band builds run on a thread pool
(NumPy releases the GIL in the hot loops) and the *array-heavy* stages run
under pjit on the device mesh:

  * ``sat_pjit``       — the (1, y, y^2) integral images, row-band sharded;
  * ``fitting_loss_batched`` — Algorithm 5 evaluated for MANY candidate
    trees at once (the hyperparameter-tuning inner loop), blocks sharded
    over the mesh and one psum at the end.
"""
from __future__ import annotations

import concurrent.futures as _fut
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import compat_set_mesh

from .coreset import SignalCoreset, signal_coreset
from .streaming import compose, recompress

__all__ = ["sharded_coreset", "sat_pjit", "fitting_loss_batched"]


def sharded_coreset(values: np.ndarray, k: int, eps: float, num_bands: int,
                    *, recompress_result: bool = False, max_workers: int | None = None,
                    share_tolerance: bool = True, _stats=None, **kw) -> SignalCoreset:
    """Build per-row-band coresets in parallel and compose them.

    ``share_tolerance``: derive the per-block opt1 cap from a *global* sigma
    estimate (one cheap greedy k-tree pass — on a real cluster, a
    tree-reduction over band statistics) and share it across bands.  The
    Lemma-14 error budget sums over intersected blocks globally, so a global
    cap keeps |C| at the single-build size; per-band caps (share_tolerance=
    False, the pure merge-reduce setting) are also valid but ~bands-times
    larger.

    ``_stats`` (internal): prebuilt full-signal integral images for the
    sigma estimate — the serving engine maintains them incrementally via
    ``delta_sat``, sparing every rebuild of a mutating signal the O(N)
    from-scratch re-SAT here.
    """
    y = np.asarray(values, np.float64)
    n = y.shape[0]
    if share_tolerance and "tolerance_override" not in kw:
        from .segmentation import greedy_tree
        from .fitting_loss import true_loss
        from .stats import PrefixStats
        ps = _stats if _stats is not None else PrefixStats.build(y)
        g = greedy_tree(ps, k)
        sigma = max(true_loss(y, g.rects, g.labels, ps=ps) / 4.0, 1e-12)
        kw = dict(kw, tolerance_override=eps * eps * sigma / max(k, 1))
    bounds = np.linspace(0, n, num_bands + 1).astype(int)
    bands = [(int(bounds[i]), int(bounds[i + 1])) for i in range(num_bands)
             if bounds[i + 1] > bounds[i]]
    with _fut.ThreadPoolExecutor(max_workers=max_workers or len(bands)) as ex:
        parts = list(ex.map(lambda b: signal_coreset(y[b[0]:b[1]], k, eps, **kw), bands))
    cs = compose(parts, [b[0] for b in bands], n_total=n)
    return recompress(cs) if recompress_result else cs


# ----------------------------------------------------------------- pjit SAT
@partial(jax.jit, static_argnames=("axis_name",))
def _sat_kernel(y: jnp.ndarray, axis_name=None):
    w0 = jnp.ones_like(y)
    stk = jnp.stack([w0, y, y * y], axis=0)          # (3, n, m)
    s = jnp.cumsum(jnp.cumsum(stk, axis=1), axis=2)
    return s


def sat_pjit(values, mesh=None, data_axis: str = "data"):
    """Integral images under pjit: rows sharded over the data axis; the
    cross-band carry is resolved by XLA's partitioned cumsum (a scan +
    collective-permute chain on TPU)."""
    y = jnp.asarray(values, jnp.float32)
    if mesh is None:
        return _sat_kernel(y)
    from jax.sharding import NamedSharding, PartitionSpec as P
    yd = jax.device_put(y, NamedSharding(mesh, P(data_axis, None)))
    with compat_set_mesh(mesh):
        out = jax.jit(_sat_kernel,
                      out_shardings=NamedSharding(mesh, P(None, data_axis, None)))(yd)
    return out


# ------------------------------------------------- batched Algorithm 5 eval
def fitting_loss_batched(cs: SignalCoreset, seg_rects: np.ndarray,
                         seg_labels: np.ndarray, mesh=None,
                         data_axis: str = "data", backend: str | None = None):
    """Evaluate T candidate segmentations at once: seg_rects (T, K, 4),
    seg_labels (T, K).  Returns (T,).

    Without a mesh this is the dispatched ``repro.ops.fitting_loss_batched``
    (numpy oracle / jitted xla / batched Pallas kernel, by selection rules
    or the explicit ``backend=``).  With a mesh, blocks are sharded over
    ``data_axis`` and every device scores its shard against all T trees
    through the same canonical dense math the xla backend jits
    (``kernels.fitting_loss.ref.fitting_loss_batched_ref``), then one psum.
    """
    if mesh is None:
        from repro import ops
        return ops.fitting_loss_batched(cs, np.asarray(seg_rects),
                                        np.asarray(seg_labels),
                                        backend=backend)

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.fitting_loss.ref import fitting_loss_batched_ref

    rects = jnp.asarray(cs.rects, jnp.float32)
    lab4 = jnp.asarray(cs.labels, jnp.float32)
    w4 = jnp.asarray(cs.weights, jnp.float32)
    sr = jnp.asarray(seg_rects, jnp.float32)
    sl = jnp.asarray(seg_labels, jnp.float32)
    B = rects.shape[0]
    shards = int(np.prod([mesh.shape[a] for a in (data_axis,)]))
    pad = (-B) % shards
    if pad:
        # zero-weight padding blocks contribute no loss
        rects = jnp.pad(rects, ((0, pad), (0, 0)))
        lab4 = jnp.pad(lab4, ((0, pad), (0, 0)))
        w4 = jnp.pad(w4, ((0, pad), (0, 0)))
    sharding = NamedSharding(mesh, P(data_axis, None))
    with compat_set_mesh(mesh):
        f = jax.jit(fitting_loss_batched_ref,
                    in_shardings=(sharding, sharding, sharding, None, None),
                    out_shardings=NamedSharding(mesh, P()))
        return np.asarray(f(rects, lab4, w4, sr, sl))
