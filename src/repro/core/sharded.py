"""Distributed coreset construction and evaluation on a JAX mesh.

The construction is embarrassingly parallel over row bands (coresets of
disjoint sub-signals compose exactly — see streaming.py).  On a real
cluster each host builds the coreset of the row band whose data it owns
(data never leaves the host: only the tiny coresets are gathered), which is
how the paper's challenge (iv) (parallel training of a single tree) is met.
In this single-process container the per-band builds run on a thread pool
(NumPy releases the GIL in the hot loops) and the *array-heavy* stages run
under pjit on the device mesh:

  * ``sat_pjit``       — the (1, y, y^2) integral images, row-band sharded;
  * ``fitting_loss_batched`` — Algorithm 5 evaluated for MANY candidate
    trees at once (the hyperparameter-tuning inner loop), blocks sharded
    over the mesh and one psum at the end.
"""
from __future__ import annotations

import concurrent.futures as _fut
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import compat_set_mesh, compat_shard_map

from .coreset import SignalCoreset, signal_coreset
from .streaming import compose, recompress

__all__ = ["sharded_coreset", "shared_tolerance", "band_bounds", "sat_pjit",
           "fitting_loss_batched"]


def shared_tolerance(values: np.ndarray, k: int, eps: float,
                     _stats=None) -> float:
    """The global per-block opt1 cap (``tolerance_override``) shared across
    band builds: one cheap greedy k-tree pass estimates sigma, and the
    Lemma-14 budget ``eps^2 * sigma / k`` is split over intersected blocks
    globally.  Extracted so every band-parallel caller — the thread-pool
    path below and the cluster coordinator's scatter/gather — computes the
    *identical* float (same op order), which is what keeps their composed
    coresets bitwise fingerprint-equal.
    """
    from .segmentation import greedy_tree
    from .fitting_loss import true_loss
    from .stats import PrefixStats
    y = np.asarray(values, np.float64)
    ps = _stats if _stats is not None else PrefixStats.build(y)
    g = greedy_tree(ps, k)
    sigma = max(true_loss(y, g.rects, g.labels, ps=ps) / 4.0, 1e-12)
    return eps * eps * sigma / max(k, 1)


def band_bounds(n: int, num_bands: int) -> list[tuple[int, int]]:
    """The canonical row-band split: linspace bounds, empty bands dropped.
    Shared by the thread-pool composer and the cluster's band-ownership map
    (worker i owns band i) so both partitions are always identical."""
    bounds = np.linspace(0, n, num_bands + 1).astype(int)
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(num_bands)
            if bounds[i + 1] > bounds[i]]


def sharded_coreset(values: np.ndarray, k: int, eps: float, num_bands: int,
                    *, recompress_result: bool = False, max_workers: int | None = None,
                    share_tolerance: bool = True, _stats=None, **kw) -> SignalCoreset:
    """Build per-row-band coresets in parallel and compose them.

    ``share_tolerance``: derive the per-block opt1 cap from a *global* sigma
    estimate (one cheap greedy k-tree pass — on a real cluster, a
    tree-reduction over band statistics) and share it across bands.  The
    Lemma-14 error budget sums over intersected blocks globally, so a global
    cap keeps |C| at the single-build size; per-band caps (share_tolerance=
    False, the pure merge-reduce setting) are also valid but ~bands-times
    larger.

    ``_stats`` (internal): prebuilt full-signal integral images for the
    sigma estimate — the serving engine maintains them incrementally via
    ``delta_sat``, sparing every rebuild of a mutating signal the O(N)
    from-scratch re-SAT here.
    """
    y = np.asarray(values, np.float64)
    n = y.shape[0]
    if share_tolerance and "tolerance_override" not in kw:
        kw = dict(kw, tolerance_override=shared_tolerance(y, k, eps, _stats))
    bands = band_bounds(n, num_bands)
    with _fut.ThreadPoolExecutor(max_workers=max_workers or len(bands)) as ex:
        parts = list(ex.map(lambda b: signal_coreset(y[b[0]:b[1]], k, eps, **kw), bands))
    cs = compose(parts, [b[0] for b in bands], n_total=n)
    return recompress(cs) if recompress_result else cs


# ----------------------------------------------------------------- pjit SAT
@partial(jax.jit, static_argnames=("axis_name",))
def _sat_kernel(y: jnp.ndarray, axis_name=None):
    w0 = jnp.ones_like(y)
    stk = jnp.stack([w0, y, y * y], axis=0)          # (3, n, m)
    s = jnp.cumsum(jnp.cumsum(stk, axis=1), axis=2)
    return s


def sat_pjit(values, mesh=None, data_axis: str = "data"):
    """Integral images under pjit: rows sharded over the data axis; the
    cross-band carry is resolved by XLA's partitioned cumsum (a scan +
    collective-permute chain on TPU)."""
    y = jnp.asarray(values, jnp.float32)
    if mesh is None:
        return _sat_kernel(y)
    from jax.sharding import NamedSharding, PartitionSpec as P
    yd = jax.device_put(y, NamedSharding(mesh, P(data_axis, None)))
    with compat_set_mesh(mesh):
        out = jax.jit(_sat_kernel,
                      out_shardings=NamedSharding(mesh, P(None, data_axis, None)))(yd)
    return out


# ------------------------------------------------- batched Algorithm 5 eval
MESH_BACKEND = "pallas+shard_map"


def fitting_loss_batched(cs: SignalCoreset, seg_rects: np.ndarray,
                         seg_labels: np.ndarray, mesh=None,
                         data_axis: str = "data", backend: str | None = None,
                         interpret: bool | None = None):
    """Evaluate T candidate segmentations at once: seg_rects (T, K, 4),
    seg_labels (T, K).  Returns (T,).

    Without a mesh this is the dispatched ``repro.ops.fitting_loss_batched``
    (numpy oracle / jitted xla / batched Pallas kernel, by selection rules
    or the explicit ``backend=``).  With a mesh, blocks are sharded over
    ``data_axis`` via ``shard_map`` and every device runs the *batched
    Pallas kernel* on its own shard against all T trees, then ONE ``psum``
    folds the per-shard partial losses — the collective pattern the cluster
    scoring path rides (previously this branch pjit'ed the dense XLA ref,
    so on a pod the kernel never saw the mesh).  The dispatch profile
    records the hop under backend :data:`MESH_BACKEND` through the same
    hook the ops registry uses.
    """
    if mesh is None:
        from repro import ops
        return ops.fitting_loss_batched(cs, np.asarray(seg_rects),
                                        np.asarray(seg_labels),
                                        backend=backend)

    import time as _time

    from jax.sharding import PartitionSpec as P

    from repro.kernels.common import default_interpret
    from repro.kernels.fitting_loss.kernel import fitting_loss_batched_call
    from repro.obs import profile as _profile
    from repro.obs import span as _span

    if interpret is None:
        interpret = default_interpret()

    rects = jnp.asarray(cs.rects, jnp.float32)
    lab4 = jnp.asarray(cs.labels, jnp.float32)
    w4 = jnp.asarray(cs.weights, jnp.float32)
    sr = jnp.asarray(seg_rects, jnp.float32)
    sl = jnp.asarray(seg_labels, jnp.float32)
    B = rects.shape[0]
    T = sr.shape[0]
    shards = int(mesh.shape[data_axis])
    pad = (-B) % shards
    if pad:
        # zero-weight padding blocks contribute no loss
        rects = jnp.pad(rects, ((0, pad), (0, 0)))
        lab4 = jnp.pad(lab4, ((0, pad), (0, 0)))
        w4 = jnp.pad(w4, ((0, pad), (0, 0)))

    def _body(r, l4, wt, s_r, s_l):
        # per-shard (B/shards)-block slab through the fused Pallas kernel,
        # then the single collective of the whole dispatch
        part = fitting_loss_batched_call(r, l4, wt, s_r, s_l,
                                         interpret=interpret)
        return jax.lax.psum(part, axis_name=data_axis)

    spec = P(data_axis, None)
    f = compat_shard_map(_body, mesh,
                         in_specs=(spec, spec, spec, P(), P()),
                         out_specs=P())
    size = int(B) * int(T)
    t0 = _time.perf_counter()
    with _span("ops.dispatch", op="fitting_loss_batched",
               backend=MESH_BACKEND, size=size):
        with compat_set_mesh(mesh):
            out = np.asarray(jax.jit(f)(rects, lab4, w4, sr, sl))
    if _profile._HOOKS:
        _profile.record("fitting_loss_batched", MESH_BACKEND, size,
                        _time.perf_counter() - t0)
    return out
