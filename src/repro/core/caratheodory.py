"""Corollary 17 — exact 4-point moment representations, vectorized.

For each block B the coreset stores <= 4 weighted labels from B whose
weighted (1, y, y^2) moments *exactly* match B's.  The paper obtains them via
iterative Caratheodory elimination in R^3 (O(|B| d^3) per block).  Because
the points (y, y^2, 1) all lie on a parabola, a closed form exists:

Let a = min label, c = max label, q_b = largest label < mu, q_a = smallest
label >= mu, V = sum (y - mu)^2.  Any distribution with mean mu supported on
B's labels avoids the open interval (q_b, q_a), so

    V_min = w_b (q_b-mu)^2 + w_a (q_a-mu)^2   (inner two-point, least variance)
    V_max = M0 (mu-a)(c-mu)                   (outer two-point; Bhatia-Davis)

bracket V, and the mixture  lam * outer + (1-lam) * inner  with
lam = (V - V_min)/(V_max - V_min)  matches (M0, M1, M2) exactly with 4
non-negative weights.  This is O(1) per block after segment reductions,
always feasible, and fully vectorized across all blocks — a beyond-paper
constructive simplification (the guarantee only needs *some* exact <=4-point
representation; see Algorithm 3 line 5).

``caratheodory_reduce`` is the paper's generic iterative elimination, kept as
the test oracle.
"""
from __future__ import annotations

import numpy as np

__all__ = ["block_representatives", "caratheodory_reduce"]

_EPS = 1e-12


def block_representatives(y_flat: np.ndarray, block_id: np.ndarray, n_blocks: int,
                          w_flat: np.ndarray | None = None):
    """Exact 4-point representation of every block.

    Args:
      y_flat:   (N,) float64 labels.
      block_id: (N,) int32/int64 block index per cell (blocks tile the signal).
      n_blocks: number of blocks.
      w_flat:   optional (N,) per-point weights (weighted/merge-reduce inputs).

    Returns:
      labels  (n_blocks, 4) float64 — support labels (subset of each block's labels)
      weights (n_blocks, 4) float64 — non-negative, sum = block mass
      moments (n_blocks, 3) float64 — (M0, M1, M2), exact
    """
    y = np.asarray(y_flat, np.float64)
    bid = np.asarray(block_id)
    if w_flat is not None:
        w = np.asarray(w_flat, np.float64)
        keep = w > 0
        y, bid, w = y[keep], bid[keep], w[keep]
        M0 = np.bincount(bid, weights=w, minlength=n_blocks)
        M1 = np.bincount(bid, weights=w * y, minlength=n_blocks)
        M2 = np.bincount(bid, weights=w * y * y, minlength=n_blocks)
    else:
        M0 = np.bincount(bid, minlength=n_blocks).astype(np.float64)
        M1 = np.bincount(bid, weights=y, minlength=n_blocks)
        M2 = np.bincount(bid, weights=y * y, minlength=n_blocks)
    safe = np.maximum(M0, 1.0)
    mu = M1 / safe
    V = np.maximum(M2 - M1 * M1 / safe, 0.0)

    a = np.full(n_blocks, np.inf)
    c = np.full(n_blocks, -np.inf)
    np.minimum.at(a, bid, y)
    np.maximum.at(c, bid, y)

    mu_cell = mu[bid]
    q_a = np.full(n_blocks, np.inf)     # smallest label >= mu
    q_b = np.full(n_blocks, -np.inf)    # largest label  <  mu
    ge = y >= mu_cell
    np.minimum.at(q_a, bid[ge], y[ge])
    lt = ~ge
    np.maximum.at(q_b, bid[lt], y[lt])
    # constant / one-sided blocks: collapse the brackets onto the mean
    q_a = np.where(np.isfinite(q_a), q_a, mu)
    q_b = np.where(np.isfinite(q_b), q_b, np.where(np.isfinite(q_a), q_a, mu))
    a = np.where(np.isfinite(a), a, mu)
    c = np.where(np.isfinite(c), c, mu)

    # ---- inner two-point {q_b, q_a}: mean mu, least variance --------------
    span_i = q_a - q_b
    wi_b = np.where(span_i > _EPS, M0 * (q_a - mu) / np.maximum(span_i, _EPS), M0)
    wi_a = M0 - wi_b
    V_min = wi_b * (q_b - mu) ** 2 + wi_a * (q_a - mu) ** 2

    # ---- outer two-point {a, c}: mean mu, max variance (Bhatia-Davis) -----
    span_o = c - a
    wo_a = np.where(span_o > _EPS, M0 * (c - mu) / np.maximum(span_o, _EPS), M0)
    wo_c = M0 - wo_a
    V_max = wo_a * (a - mu) ** 2 + wo_c * (c - mu) ** 2

    denom = V_max - V_min
    lam = np.where(denom > _EPS, (V - V_min) / np.maximum(denom, _EPS), 0.0)
    lam = np.clip(lam, 0.0, 1.0)

    labels = np.stack([a, q_b, q_a, c], axis=1)
    weights = np.stack([lam * wo_a, (1 - lam) * wi_b,
                        (1 - lam) * wi_a, lam * wo_c], axis=1)
    weights = np.maximum(weights, 0.0)
    # Exactness is preserved up to fp rounding; renormalize the count so
    # downstream mass bookkeeping (Algorithm 5) sees sum(u) == |B| exactly.
    scale = M0 / np.maximum(weights.sum(axis=1), _EPS)
    weights = weights * np.where(M0 > 0, scale, 0.0)[:, None]
    moments = np.stack([M0, M1, M2], axis=1)
    return labels, weights, moments


# --------------------------------------------------------------------------
def caratheodory_reduce(points: np.ndarray, weights: np.ndarray):
    """Classic iterative Caratheodory (Theorem 16): reduce a weighted set in
    R^d to <= d+1 points with the same weighted sum and total weight.

    Reference implementation / test oracle. O(n d^3).
    """
    P = np.asarray(points, np.float64)
    w = np.asarray(weights, np.float64).copy()
    n, d = P.shape
    idx = np.arange(n)
    alive = w > 0
    while alive.sum() > d + 1:
        act = idx[alive][: d + 2]
        A = P[act]  # (d+2, d)
        # affine dependence: sum lam_i A_i = 0, sum lam_i = 0, lam != 0
        M = np.concatenate([A.T, np.ones((1, act.size))], axis=0)  # (d+1, d+2)
        _, _, vh = np.linalg.svd(M)
        lam = vh[-1]
        pos = lam > 1e-15
        if not pos.any():
            lam = -lam
            pos = lam > 1e-15
        ratios = w[act][pos] / lam[pos]
        j_local = int(np.argmin(ratios))
        alpha = float(ratios[j_local])
        w[act] = np.maximum(w[act] - alpha * lam, 0.0)
        w[act[np.flatnonzero(pos)[j_local]]] = 0.0  # exact elimination
        alive = w > 0
    keep = idx[alive]
    return keep, w[keep]
