"""Algorithm 1 (SLICEPARTITION) — greedy maximal-variance slicing of a band.

Given a horizontal band of rows [r0:r1) of the signal and a tolerance
``sigma``, partition it into vertical slices, each the *maximal* contiguous
column window whose opt1 is <= sigma.  When even a single column exceeds the
tolerance, that column is recursively partitioned horizontally (the paper's
``SLICEPARTITION(B^T, sigma)`` call).

Identical output to the paper's linear greedy scan, but each boundary is
located with a binary search over the monotone opt1 (see
``PrefixStats.max_col_extent``), so a band costs O(#slices * log m) instead
of O((r1-r0) * m).
"""
from __future__ import annotations

from .stats import PrefixStats

__all__ = ["slice_partition", "Rect"]

# A rectangle is (r0, r1, c0, c1), half-open on both axes.
Rect = tuple[int, int, int, int]


def slice_partition(ps: PrefixStats, r0: int, r1: int, sigma: float,
                    c_lo: int = 0, c_hi: int | None = None) -> list[Rect]:
    """Partition the band [r0:r1, c_lo:c_hi) into maximal slices with
    opt1 <= sigma (Algorithm 1)."""
    m = ps.shape[1]
    c_hi = m if c_hi is None else c_hi
    out: list[Rect] = []
    c0 = c_lo
    while c0 < c_hi:
        c_end = ps.max_col_extent(r0, r1, c0, sigma)
        c_end = min(c_end, c_hi)
        if c_end == c0:
            # Single column already exceeds sigma: recurse on its transpose,
            # i.e. partition the column along rows (Algorithm 1 lines 4-6).
            out.extend(_column_partition(ps, c0, r0, r1, sigma))
            c0 += 1
        else:
            out.append((r0, r1, c0, c_end))
            c0 = c_end
    return out


def _column_partition(ps: PrefixStats, c: int, r_lo: int, r_hi: int,
                      sigma: float) -> list[Rect]:
    """Greedy maximal row-windows of a single column; single cells have
    opt1 = 0 <= sigma so this always terminates with unit cells at worst."""
    out: list[Rect] = []
    r0 = r_lo
    while r0 < r_hi:
        r_end = ps.max_row_extent(c, c + 1, r0, sigma)
        r_end = min(max(r_end, r0 + 1), r_hi)  # a unit cell always fits
        out.append((r0, r_end, c, c + 1))
        r0 = r_end
    return out


def slices_count_if(ps: PrefixStats, r0: int, r1: int, sigma: float,
                    stop_above: int) -> int:
    """Number of slices SLICEPARTITION would produce, early-exiting once the
    count exceeds ``stop_above`` (used by Algorithm 2's band-growing loop so
    rejected bands don't pay for a full partition)."""
    m = ps.shape[1]
    cnt = 0
    c0 = 0
    while c0 < m:
        c_end = ps.max_col_extent(r0, r1, c0, sigma)
        if c_end == c0:
            # count the column's row-partition
            rr = r0
            while rr < r1:
                r_end = min(max(ps.max_row_extent(c0, c0 + 1, rr, sigma), rr + 1), r1)
                cnt += 1
                if cnt > stop_above:
                    return cnt
                rr = r_end
            c0 += 1
        else:
            cnt += 1
            c0 = c_end
        if cnt > stop_above:
            return cnt
    return cnt
