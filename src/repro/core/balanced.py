"""Algorithm 2 (PARTITION) — the balanced partition of the signal.

The "simplicial partition for SSE" (Definition 6 / Lemma 7): a partition of
the signal into rectangles such that (i) the number of rectangles depends on
alpha/gamma^2 but not on N, (ii) every rectangle has opt1 <= gamma^2 * sigma,
and (iii) any k-segmentation intersects only O(k*alpha/gamma) of them.

Bands of rows are grown greedily while their SLICEPARTITION stays within
1/gamma slices; when adding a row would overflow, the previous band's
partition is committed (Fig. 2 of the paper, including the single-row
overflow case, which is committed as-is).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .slice_partition import Rect, slice_partition, slices_count_if
from .stats import PrefixStats

__all__ = ["balanced_partition", "BalancedPartition"]


@dataclasses.dataclass(frozen=True)
class BalancedPartition:
    """Result of Algorithm 2 plus bookkeeping used by the coreset proofs."""

    rects: np.ndarray          # (B, 4) int64 rows of (r0, r1, c0, c1)
    band_bounds: np.ndarray    # (H+1,) row indices of committed horizontal bands
    tolerance: float           # gamma^2 * sigma: upper bound on each opt1(B)

    @property
    def num_blocks(self) -> int:
        return int(self.rects.shape[0])

    def block_id_raster(self, n: int, m: int) -> np.ndarray:
        """(n, m) int32 map cell -> block index (blocks tile the signal)."""
        out = np.full((n, m), -1, dtype=np.int32)
        for i, (r0, r1, c0, c1) in enumerate(self.rects):
            out[r0:r1, c0:c1] = i
        if (out < 0).any():
            raise AssertionError("balanced partition does not tile the signal")
        return out


def balanced_partition(ps: PrefixStats, tolerance: float,
                       max_slices: int) -> BalancedPartition:
    """PARTITION(D, gamma, sigma); see Lemma 7.

    In the paper's parameterization ``tolerance = gamma^2 * sigma`` and
    ``max_slices = 1/gamma``; they are decoupled here so the practical mode
    can pick the per-block opt1 cap and the band-width cap independently
    (see ``signal_coreset`` for both settings).
    """
    if tolerance < 0:
        raise ValueError("tolerance must be >= 0")
    n, m = ps.shape
    tol = float(tolerance)
    max_slices = max(int(max_slices), 1)

    rects: list[Rect] = []
    band_bounds = [0]
    r0 = 0
    while r0 < n:
        # Find the maximal band [r0, r1) whose partition fits in max_slices,
        # by exponential growth + binary search over the (monotone) slice
        # count — O(log H) early-exit counts per band instead of the paper's
        # one-row-at-a-time O(H) repartitions.  (If the count is locally
        # non-monotone the committed band is merely narrower than maximal,
        # which affects no guarantee — every block still satisfies the
        # tolerance and the cap.)
        if slices_count_if(ps, r0, r0 + 1, tol, stop_above=max_slices) > max_slices:
            # single-row overflow: committed as-is (Fig. 2, yellow case)
            cur = slice_partition(ps, r0, r0 + 1, tol)
            r1 = r0 + 1
        else:
            step, r1 = 1, r0 + 1
            while r1 < n:
                cand = min(r1 + step, n)
                if slices_count_if(ps, r0, cand, tol, stop_above=max_slices) <= max_slices:
                    r1 = cand
                    step *= 2
                else:
                    break
            lo, hi = r1, min(r1 + step, n)  # invariant: [r0, lo) fits
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if slices_count_if(ps, r0, mid, tol, stop_above=max_slices) <= max_slices:
                    lo = mid
                else:
                    hi = mid - 1
            r1 = lo
            cur = slice_partition(ps, r0, r1, tol)
        rects.extend(cur)
        band_bounds.append(r1)
        r0 = r1

    return BalancedPartition(
        rects=np.asarray(rects, dtype=np.int64).reshape(-1, 4),
        band_bounds=np.asarray(band_bounds, dtype=np.int64),
        tolerance=float(tol),
    )
