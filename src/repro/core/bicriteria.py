"""Algorithm 4 (BICRITERIA) — the (alpha, beta)_k approximation (Lemma 5/10/11).

The coreset pipeline only consumes a scalar from this stage: a lower bound
``sigma <= opt_k(D)``.  We compute the *maximum of several certified lower
bounds* (each valid by the paper's own intersection-counting argument —
Observation 9 + "keep the blocks any k-segmentation cannot all intersect"):

(a) **Iterative removal** (the paper's Algorithm 4): per iteration, candidate
    disjoint sub-signals are formed (heavy-row 1-dim split / row-groups x
    column-intervals / heavy columns); discarding as many blocks as any
    k-segmentation could intersect and keeping the smallest-opt1 remainder
    gives  sum_{B in kept_i} opt1(B) <= opt_k(D)  (Lemma 10(i)).  The
    intersection budget z is computed *adaptively* from the candidate
    geometry:  z = 2k * (H + V), where H (resp. V) is the max number of
    blocks of height >= 2 (resp. width >= 2) that one horizontal (resp.
    vertical) boundary line can strictly cross — exactly the quantity the
    paper's proof bounds with worst-case constants.

(b) **Per-row / per-column 1-dim bounds**: the restriction of the optimal
    k-segmentation to any single row is a <= k-segmentation of that row, so
    sum_i opt_k(row_i) <= opt_k(D); each opt_k(row_i) is itself lower-bounded
    by the 1-dim interval scheme (t' = 4k equal intervals, keep the t'-2k
    smallest opt1 — Lemma 10, 1-dim case).  Columns likewise.  These are
    O(N) and much tighter than (a) on noisy signals when N >> k but
    N << 64 k^2 (the regime where (a)'s grouped scheme degenerates — which
    includes the paper's own experiments; see DESIGN.md §3).

``fidelity="paper"`` restores the paper's worst-case constants
(nu > 50, gamma_1d >= 8, r = 2(nu k)^2) for the theory-faithful path.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .stats import PrefixStats, opt1_from_sums

__all__ = ["bicriteria", "BicriteriaResult"]


@dataclasses.dataclass
class BicriteriaResult:
    sigma: float            # certified lower bound on opt_k(D): max of all bounds
    ell: float              # loss of the (alpha,beta)_k segmentation s (iterative scheme)
    alpha_hat: float        # ell / sigma (realized alpha)
    n_iterations: int
    n_blocks: int           # number of blocks of s (incl. final singletons)
    iter_losses: list[float]
    sigma_iter: float = 0.0     # bound (a)
    sigma_rows: float = 0.0     # bound (b), rows
    sigma_cols: float = 0.0     # bound (b), columns


def _keep_smallest(opt1s: np.ndarray, z: int) -> np.ndarray:
    """Indices of the |B'|-z blocks with smallest opt1 (at least 1 kept;
    keeping a subset of the smallest only shrinks the certified sum)."""
    keep = max(1, opt1s.size - z)
    return np.argpartition(opt1s, keep - 1)[:keep] if keep < opt1s.size else np.arange(opt1s.size)


# --------------------------------------------------------------- bound (b)
def _rowwise_interval_bound(w0: np.ndarray, w1: np.ndarray, w2: np.ndarray,
                            k: int) -> float:
    """sum_i [ sum of the (t'-2k) smallest opt1 over t'=4k equal column
    intervals of row i ]  <=  sum_i opt_k(row_i)  <=  opt_k(D)."""
    n, m = w0.shape
    t = max(4 * k, 4)
    if m < 2:
        return 0.0
    bounds = np.unique(np.linspace(0, m, min(t, m) + 1).astype(np.int64))
    p0 = np.concatenate([np.zeros((n, 1)), np.cumsum(w0, axis=1)], axis=1)
    p1 = np.concatenate([np.zeros((n, 1)), np.cumsum(w1, axis=1)], axis=1)
    p2 = np.concatenate([np.zeros((n, 1)), np.cumsum(w2, axis=1)], axis=1)
    lo, hi = bounds[:-1], bounds[1:]
    s0 = p0[:, hi] - p0[:, lo]
    s1 = p1[:, hi] - p1[:, lo]
    s2 = p2[:, hi] - p2[:, lo]
    o = opt1_from_sums(s0, s1, s2)                     # (n, t)
    keep = max(o.shape[1] - 2 * k, 0)
    if keep == 0:
        return 0.0
    o_sorted = np.sort(o, axis=1)[:, :keep]
    return float(o_sorted.sum())


# --------------------------------------------------------------------- main
def bicriteria(values: np.ndarray | None, k: int, *, nu: float = 8.0,
               gamma_1d: float = 4.0, fidelity: str = "practical",
               moments: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
               max_practical_iters: int = 8) -> BicriteriaResult:
    """Compute the (alpha, beta)_k bi-criteria approximation of Lemma 5.

    Either ``values`` (dense signal) or ``moments`` = (w0, w1, w2) per-cell
    moment rasters (weighted/sparse signal, used by merge-reduce).

    In practical mode the iterative scheme is capped at
    ``max_practical_iters`` iterations: the paper's own analysis needs
    Theta(k log N) iterations each removing a 1/O(k) fraction (that is the
    O(Nk) in Theorem 8), and at practical N its certified bound is dominated
    by the O(N) row/column bounds anyway — see the profile notes in
    EXPERIMENTS.md.  ``fidelity="paper"`` runs it to completion.
    """
    if fidelity == "paper":
        nu, gamma_1d = 51.0, 8.0
    if moments is None:
        y = np.asarray(values, dtype=np.float64)
        w0, w1, w2 = np.ones_like(y), y, y * y
    else:
        w0, w1, w2 = (np.asarray(a, np.float64) for a in moments)
    n, m = w0.shape
    N = n * m
    live = w0 > 0
    n_live = float(w0.sum())
    threshold = max(int(k * math.log2(N + 1)), 4)

    sigma_rows = _rowwise_interval_bound(w0, w1, w2, k)
    sigma_cols = _rowwise_interval_bound(w0.T, w1.T, w2.T, k)

    iter_losses: list[float] = []
    total_loss = 0.0
    n_blocks = 0
    max_iters = int(8 * nu * k * math.log2(N + 2)) + 16  # safety valve
    if fidelity != "paper":
        max_iters = min(max_iters, max_practical_iters)

    for _ in range(max_iters):
        if n_live <= threshold:
            break
        ps = PrefixStats.build_moments(w0, w1, w2, mask=live)
        row_sizes = ps.p0[1:, -1] - ps.p0[:-1, -1]   # live mass per row
        heavy_row_thresh = n_live / (nu * k)

        hr = int(np.argmax(row_sizes))
        if row_sizes[hr] >= heavy_row_thresh and row_sizes[hr] > 0:
            # ---- 1-dim case on the heavy row (Alg. 4 lines 4-6) ----------
            opt1s, rects = _heavy_row_candidates(ps, hr, float(row_sizes[hr]),
                                                 max(int(gamma_1d * k), 4))
            keep = _keep_smallest(opt1s, 2 * k)
        else:
            groups = _row_groups(row_sizes, heavy_row_thresh)
            # heavy-column decision threshold: a constant mass fraction in
            # practical mode (the paper's 1/(2(nu k)^2) declares near-every
            # column heavy at practical N, forcing the slow case (ii));
            # interval count inside light groups stays ~8k per the z-budget.
            col_frac = 2.0 * (nu * k) ** 2 if fidelity == "paper" else 8.0
            t_split = (2.0 * (nu * k) ** 2 if fidelity == "paper"
                       else float(max(8 * k, 4)))
            cand = _grouped_candidates(ps, groups, col_frac, t_split, k)
            if cand is None:
                break  # degenerate tiny remainder; finish with singletons
            opt1s, rects, z = cand
            keep = _keep_smallest(opt1s, z)

        kept_loss = float(opt1s[keep].sum())
        iter_losses.append(kept_loss)
        total_loss += kept_loss
        n_blocks += keep.size
        for idx in keep:
            r0, r1, c0, c1 = rects[idx]
            live[r0:r1, c0:c1] = False
        new_live = float(w0[live].sum())
        if n_live - new_live <= 0:
            break  # safety: guarantee progress
        n_live = new_live

    # Remaining cells become singleton blocks (opt1 = 0): contribute no loss.
    n_blocks += int(live.sum())
    sigma_iter = max(iter_losses) if iter_losses else 0.0
    sigma = max(sigma_iter, sigma_rows, sigma_cols)
    return BicriteriaResult(
        sigma=float(sigma),
        ell=float(total_loss),
        alpha_hat=float(total_loss / sigma) if sigma > 0 else 1.0,
        n_iterations=len(iter_losses),
        n_blocks=n_blocks,
        iter_losses=iter_losses,
        sigma_iter=float(sigma_iter),
        sigma_rows=float(sigma_rows),
        sigma_cols=float(sigma_cols),
    )


# --------------------------------------------------------------------------
def _heavy_row_candidates(ps: PrefixStats, row: int, row_mass: float, t_prime: int):
    """Split the heavy row's live mass into t' contiguous ~equal-mass column
    intervals; return their opt1s and rects."""
    n, m = ps.shape
    cum = ps.p0[row + 1, :] - ps.p0[row, :]          # (m+1,) cumulative live mass
    targets = np.arange(1, t_prime + 1) * (row_mass / t_prime)
    bounds = np.unique(np.clip(np.searchsorted(cum, targets - 1e-9, side="left"), 1, m))
    starts = np.concatenate([[0], bounds[:-1]])
    s0, s1, s2 = ps.sums(row, row + 1, starts, bounds)
    opt1s = opt1_from_sums(s0, s1, s2)
    rects = [(row, row + 1, int(a), int(b)) for a, b in zip(starts, bounds)]
    return opt1s, rects


def _row_groups(row_sizes: np.ndarray, target: float) -> list[tuple[int, int]]:
    """Greedy contiguous row groups with live mass in [target, 3*target)
    (tail merged into its predecessor)."""
    groups: list[tuple[int, int]] = []
    acc, start = 0.0, 0
    n = row_sizes.size
    for i in range(n):
        acc += row_sizes[i]
        if acc >= target:
            groups.append((start, i + 1))
            start, acc = i + 1, 0.0
    if start < n:
        if groups:
            g0, _ = groups.pop()
            groups.append((g0, n))
        else:
            groups.append((0, n))
    return groups


def _grouped_candidates(ps: PrefixStats, groups: list[tuple[int, int]],
                        col_frac: float, t_split: float, k: int):
    """Cases (i)/(ii) of Lemma 10: column-interval blocks of light groups, or
    the heavy column of each heavy group.  Returns (opt1s, rects, z)."""
    m = ps.shape[1]
    light, heavy = [], []   # (g0, g1, size) / (g0, g1, col)
    for g0, g1 in groups:
        col_counts = np.diff(ps.p0[g1, :] - ps.p0[g0, :])
        size = float(col_counts.sum())
        if size <= 0:
            continue
        thresh = size / col_frac
        hc = int(np.argmax(col_counts))
        if col_counts[hc] >= thresh and col_counts[hc] > 0:
            heavy.append((g0, g1, hc))
        else:
            light.append((g0, g1, size))

    if not light and not heavy:
        return None

    rects: list[tuple[int, int, int, int]] = []
    if len(light) >= len(heavy) and light:
        # Case (i): vertically partition every light group into ~equal-mass
        # column intervals (each column is lighter than the target, so the
        # greedy split is feasible).
        max_per_group = 1
        for g0, g1, size in light:
            cum = ps.p0[g1, :] - ps.p0[g0, :]
            t_g = max(int(t_split), 1)
            targets = np.arange(1, t_g + 1) * (size / t_g)
            bounds = np.unique(np.clip(np.searchsorted(cum, targets - 1e-9, "left"), 1, m))
            starts = np.concatenate([[0], bounds[:-1]])
            for a, b in zip(starts, bounds):
                rects.append((g0, g1, int(a), int(b)))
            max_per_group = max(max_per_group, len(bounds))
        # Adaptive budget: one horizontal boundary line lies inside exactly
        # one group -> crosses <= (intervals of that group) blocks; one
        # vertical line crosses <= 1 interval per group.
        z = 2 * k * (max_per_group + len(light))
    else:
        # Case (ii): the heaviest column of each heavy group.  Single-column
        # blocks cannot be split by vertical boundaries, and a horizontal
        # line lies inside exactly one group -> z = 2k (the 1-dim budget).
        for g0, g1, hc in heavy:
            rects.append((g0, g1, hc, hc + 1))
        z = 2 * k

    arr = np.asarray(rects, dtype=np.int64)
    s0, s1, s2 = ps.sums(arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3])
    opt1s = opt1_from_sums(s0, s1, s2)
    return opt1s, rects, z
