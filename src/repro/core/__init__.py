# The paper's primary contribution — (k, eps)-coresets for decision trees
# of 2D signals (NeurIPS 2021) — implemented as a composable library:
# prefix statistics, the bi-criteria lower bound, the balanced partition,
# Caratheodory block compression, the Algorithm-5 query engine, plus
# streaming (merge-reduce) and mesh-distributed construction.
from .stats import PrefixStats, opt1_from_sums
from .slice_partition import slice_partition
from .balanced import BalancedPartition, balanced_partition
from .bicriteria import BicriteriaResult, bicriteria
from .caratheodory import block_representatives, caratheodory_reduce
from .coreset import SignalCoreset, signal_coreset, signal_coreset_to_size
from .fitting_loss import fitting_loss, true_loss, overlap_counts
from .segmentation import (Segmentation, greedy_tree, optimal_labels,
                           optimal_tree_dp, random_tree_segmentation,
                           segment_1d_dp)
from .streaming import StreamingBuilder, compose, recompress, weighted_signal_coreset
from .sharded import fitting_loss_batched, sat_pjit, sharded_coreset

__all__ = [
    "PrefixStats", "opt1_from_sums", "slice_partition", "BalancedPartition",
    "balanced_partition", "BicriteriaResult", "bicriteria",
    "block_representatives", "caratheodory_reduce", "SignalCoreset",
    "signal_coreset", "signal_coreset_to_size", "fitting_loss", "true_loss",
    "overlap_counts",
    "Segmentation", "greedy_tree", "optimal_labels", "optimal_tree_dp",
    "random_tree_segmentation", "segment_1d_dp", "StreamingBuilder",
    "compose", "recompress", "weighted_signal_coreset",
    "fitting_loss_batched", "sat_pjit", "sharded_coreset",
]
