"""Algorithm 3 (SIGNAL-CORESET) — end-to-end (k, eps)-coreset construction.

Pipeline (Theorem 8):
  1. bi-criteria stage -> certified lower bound sigma <= opt_k(D);
  2. balanced partition with tolerance gamma^2 * sigma;
  3. per-block exact <=4-point Caratheodory representation, coordinates
     snapped to the block corners (Line 6 of Algorithm 3).

Two gamma regimes:
  * ``fidelity="practical"`` (default): gamma = eps — the regime the paper's
    own experiments run in (Section 5 uses eps to control the size/accuracy
    trade-off; the worst-case gamma = eps^2/(beta k) would force |C| >= N on
    real data, as the paper itself observes in "Coreset size").
  * ``fidelity="paper"``: gamma = eps^2 / (k * alpha_hat), the theory-faithful
    setting (with the adaptive alpha_hat = ell/sigma standing in for beta; see
    DESIGN.md §3) — used by the guarantee property tests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time

import numpy as np

from .balanced import BalancedPartition, balanced_partition
from .bicriteria import BicriteriaResult, bicriteria
from .caratheodory import block_representatives
from .stats import PrefixStats

__all__ = ["SignalCoreset", "signal_coreset"]


@dataclasses.dataclass
class SignalCoreset:
    """The (C, u) data structure of Definition 3 (block-structured form).

    Each row i describes one block of the balanced partition:
      rects[i]   = (r0, r1, c0, c1)  half-open corner coordinates
      labels[i]  = 4 support labels (a subset of the block's labels)
      weights[i] = 4 non-negative weights, sum = block area
      moments[i] = exact (M0, M1, M2) of the block (redundant with
                   labels/weights — kept for O(1) non-intersected evaluation)
    """

    n: int
    m: int
    k: int
    eps: float
    rects: np.ndarray     # (B, 4) int64
    labels: np.ndarray    # (B, 4) float64
    weights: np.ndarray   # (B, 4) float64
    moments: np.ndarray   # (B, 3) float64
    sigma: float
    tolerance: float      # per-block opt1 cap used by the balanced partition
    max_slices: int       # band-width cap (1/gamma in the paper's terms)
    bicriteria: BicriteriaResult
    build_seconds: float
    certified: bool = True  # False when the heuristic sigma floor engaged

    # ------------------------------------------------------------------ views
    @property
    def num_blocks(self) -> int:
        return int(self.rects.shape[0])

    @property
    def size(self) -> int:
        """|C| — number of stored weighted points (4 per block)."""
        return 4 * self.num_blocks

    def compression_ratio(self) -> float:
        return self.size / float(self.n * self.m)

    def as_points(self, style: str = "mean"):
        """Flat weighted-point view for downstream solvers (paper §5):
        coordinates are the 4 corners of each block (Line 6).

        ``style="mean"`` (default for tree training): each corner carries the
        block's mean label with weight M0/4 — measured to beat both the raw
        Caratheodory labels and equal-size uniform sampling for forest
        training (regression trees consume block means; see EXPERIMENTS.md
        §Perf/quality).  First two moments are preserved exactly.
        ``style="caratheodory"``: the exact (M0, M1, M2) representation the
        Algorithm-5 query engine uses (paper-literal).

        Returns (X (P,2), y (P,), w (P,)) with zero-weight points dropped.
        """
        r0, r1, c0, c1 = (self.rects[:, i] for i in range(4))
        # corner order: (r0,c0), (r0,c1-1), (r1-1,c0), (r1-1,c1-1)
        rows = np.stack([r0, r0, r1 - 1, r1 - 1], axis=1)
        cols = np.stack([c0, c1 - 1, c0, c1 - 1], axis=1)
        X = np.stack([rows.ravel(), cols.ravel()], axis=1).astype(np.float64)
        if style == "mean":
            mu = self.moments[:, 1] / np.maximum(self.moments[:, 0], 1e-300)
            y = np.repeat(mu, 4)
            w = np.repeat(self.moments[:, 0] / 4.0, 4)
        else:
            y = self.labels.ravel()
            w = self.weights.ravel()
        keep = w > 0
        return X[keep], y[keep], w[keep]

    def total_mass(self) -> float:
        return float(self.weights.sum())

    @property
    def nbytes(self) -> int:
        """Payload bytes of the stored arrays (cache-accounting size)."""
        return int(self.rects.nbytes + self.labels.nbytes
                   + self.weights.nbytes + self.moments.nbytes)

    def fingerprint(self) -> str:
        """Stable content hash of the block geometry + exact moments.

        Two coresets with equal fingerprints answer every Algorithm-5 query
        identically (the loss only reads rects/labels/weights/moments), so
        this is a well-defined cache/ETag identity for the serving layer.
        """
        h = hashlib.blake2b(digest_size=16)
        h.update(np.int64([self.n, self.m, self.k, self.num_blocks]).tobytes())
        h.update(np.float64([self.eps]).tobytes())
        h.update(np.ascontiguousarray(self.rects, np.int64).tobytes())
        h.update(np.ascontiguousarray(self.moments, np.float64).tobytes())
        h.update(np.ascontiguousarray(self.labels, np.float64).tobytes())
        h.update(np.ascontiguousarray(self.weights, np.float64).tobytes())
        return h.hexdigest()

    def __repr__(self) -> str:
        return (f"SignalCoreset(n={self.n}, m={self.m}, k={self.k}, "
                f"eps={self.eps:g}, size={self.size}, "
                f"ratio={self.compression_ratio():.3g}, "
                f"certified={self.certified}, fp={self.fingerprint()[:10]})")


def resolve_partition_params(sigma: float, k: int, eps: float, fidelity: str,
                             alpha_hat: float) -> tuple[float, int]:
    """(tolerance, max_slices) per fidelity mode.

    paper:      gamma = eps^2/(k*alpha_hat); tolerance = gamma^2 sigma,
                max_slices = 1/gamma  (Lemma 7's parameterization).
    practical:  tolerance = eps^2 sigma / k and max_slices = 2 sqrt(k)/eps
                (gamma_eff = eps/sqrt(k)).  A k-leaf tree intersects
                I = O(k) blocks, so its Lemma-14 error budget is
                I * tolerance * (1 + 1/eps) ~ eps * sigma * (I/k) <~
                eps * opt_k — i.e. the relative error stays <= O(eps)
                uniformly in k.  Calibrated on the benchmark suite (see
                EXPERIMENTS.md §Guarantee).
    """
    if fidelity == "paper":
        gamma = eps * eps / (k * max(alpha_hat, 1.0))
        gamma = float(np.clip(gamma, 1e-6, 1.0))
        return gamma * gamma * sigma, max(int(1.0 / gamma), 1)
    tol = eps * eps * sigma / max(k, 1)
    max_slices = max(16, int(2.0 * np.sqrt(k) / eps))
    return float(tol), int(max_slices)


def signal_coreset(values: np.ndarray, k: int, eps: float, *,
                   fidelity: str = "practical", nu: float = 8.0,
                   gamma_1d: float = 4.0, sigma_mode: str = "auto",
                   mask: np.ndarray | None = None,
                   tolerance_override: float | None = None,
                   max_slices_override: int | None = None,
                   _sigma_hint=None,
                   _stats: PrefixStats | None = None) -> SignalCoreset:
    """SIGNAL-CORESET(D, k, eps); see Theorem 8.

    ``mask`` (optional) marks observed cells; unobserved cells carry no mass
    (the §5 missing-value protocol compresses only the available data).

    ``_stats`` (internal) supplies prebuilt integral images of ``values`` —
    the serving engine maintains them incrementally via the ``delta_sat``
    op, so repeated (k, eps) builds of a mutating signal skip the O(N)
    prefix-sum rebuild.

    ``sigma_mode``:
      * "auto" (default): sigma = max(certified bi-criteria bound,
        greedy-tree-loss / 4).  The certified bounds vanish when
        k >~ min(n, m)/4 (the paper's own experimental regime: its worst-case
        machinery needs ~64 k^2 cells); the greedy k-tree loss is an upper
        bound on opt_k, so loss/4 is a heuristic lower bound — exactly the
        practical stance of the paper's §5 (empirical eps).  ``certified``
        on the result records whether the heuristic kicked in.
      * "certified": bi-criteria bounds only (used by the guarantee tests).
    """
    if not (0.0 < eps < 1.0):
        raise ValueError("eps must be in (0,1)")
    t0 = time.perf_counter()
    y = np.asarray(values, dtype=np.float64)
    n, m = y.shape
    if mask is not None:
        from .streaming import weighted_signal_coreset
        rows, cols = np.nonzero(mask)
        return weighted_signal_coreset(
            n, m, rows, cols, y[mask], np.ones(rows.size), k, eps,
            fidelity=fidelity, tolerance_override=tolerance_override,
            max_slices_override=max_slices_override, _sigma_hint=_sigma_hint)

    if _stats is not None and _stats.shape != y.shape:
        raise ValueError(f"_stats shape {_stats.shape} != signal {y.shape}")
    ps_full = PrefixStats.build(y) if _stats is None else _stats
    if _sigma_hint is not None:       # size-bisection path: sigma known
        sigma, certified, bic = _sigma_hint
    else:
        bic = bicriteria(y, k, nu=nu, gamma_1d=gamma_1d, fidelity=fidelity)
        sigma = bic.sigma
        certified = True
        if sigma_mode == "auto" and fidelity != "paper":
            from .segmentation import greedy_tree
            from .fitting_loss import true_loss
            g = greedy_tree(ps_full, k)
            # /6 calibrated on the worst family (smooth fields): max rel err
            # stays ~eps/2 at eps=0.1 (see EXPERIMENTS.md §Guarantee)
            heur = true_loss(y, g.rects, g.labels, ps=ps_full) / 6.0
            if heur > sigma:
                sigma, certified = heur, False

    tol, max_slices = resolve_partition_params(sigma, k, eps, fidelity, bic.alpha_hat)
    if tolerance_override is not None:
        tol = float(tolerance_override)
    if max_slices_override is not None:
        max_slices = int(max_slices_override)

    part: BalancedPartition = balanced_partition(ps_full, tol, max_slices)

    block_id = part.block_id_raster(n, m)
    labels, weights, moments = block_representatives(
        y.ravel(), block_id.ravel(), part.num_blocks)

    return SignalCoreset(
        n=n, m=m, k=k, eps=eps,
        rects=part.rects, labels=labels, weights=weights, moments=moments,
        sigma=float(sigma), tolerance=tol, max_slices=max_slices,
        bicriteria=bic, build_seconds=time.perf_counter() - t0,
        certified=certified,
    )


def signal_coreset_to_size(values: np.ndarray, k: int, target_frac: float,
                           *, mask: np.ndarray | None = None,
                           iters: int = 7, **kw) -> SignalCoreset:
    """Build a coreset of ~``target_frac`` of the input size by bisecting the
    block tolerance (the paper's Fig-4 experiments sweep compression size
    directly; eps is the dual knob).  Monotone: larger tolerance -> coarser
    partition -> fewer points.  The bi-criteria stage runs once; bisection
    re-runs only the balanced partition + block compression.
    """
    y = np.asarray(values, dtype=np.float64)
    base = signal_coreset(y, k, 0.5, mask=mask, **kw)
    if base.compression_ratio() <= target_frac:
        return base

    def rebuild(tol):
        return signal_coreset(y, k, 0.5, mask=mask, tolerance_override=tol,
                              max_slices_override=base.max_slices,
                              sigma_mode="skip",
                              _sigma_hint=(base.sigma, base.certified,
                                           base.bicriteria), **kw)

    lo = hi = base.tolerance + 1e-30
    cs = base
    while cs.compression_ratio() > target_frac and hi < 1e12 * lo:
        hi *= 8.0
        cs = rebuild(hi)
    best = cs
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        cs = rebuild(mid)
        if cs.compression_ratio() > target_frac:
            lo = mid
        else:
            hi = mid
            best = cs
            if cs.compression_ratio() > 0.75 * target_frac:
                break              # close enough from below
    return best
