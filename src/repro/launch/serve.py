"""Batched serving driver: prefill + decode with a KV/SSM cache.

A minimal continuous-batching front: requests accumulate into a fixed-size
batch; prefill runs once per batch (right-padded), then the decode loop
samples until max_new_tokens.  Runs reduced configs on CPU; on a real mesh
the same code pjit-shards via the cache/batch specs.

  python -m repro.launch.serve --arch qwen2-0.5b --reduced --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.models import decode_step, init_cache, init_params

__all__ = ["generate", "main"]


def generate(cfg, params, prompts: np.ndarray, max_new_tokens: int,
             temperature: float = 1.0, seed: int = 0,
             greedy: bool = False) -> np.ndarray:
    """prompts: (B, Lp) int32 (right-aligned, no padding support needed for
    the synthetic demo).  Returns (B, Lp + max_new_tokens)."""
    B, Lp = prompts.shape
    max_len = Lp + max_new_tokens
    cache = init_cache(cfg, B, max_len)

    # prefill: teacher-forced pass through the decode path to fill the cache
    # (keeps one compiled step; production prefill uses the chunked forward)
    dec = jax.jit(lambda p, c, b: decode_step(cfg, p, c, b))
    toks = jnp.asarray(prompts, jnp.int32)
    logits = None
    for t in range(Lp):
        logits, cache = dec(params, cache, {"tokens": toks[:, t:t + 1]})

    rng = jax.random.PRNGKey(seed)
    out = [toks]
    cur = None
    for i in range(max_new_tokens):
        lf = logits[:, -1].astype(jnp.float32)
        if greedy or temperature <= 0:
            cur = jnp.argmax(lf, axis=-1).astype(jnp.int32)[:, None]
        else:
            rng, k = jax.random.split(rng)
            cur = jax.random.categorical(k, lf / temperature).astype(jnp.int32)[:, None]
        out.append(cur)
        logits, cache = dec(params, cache, {"tokens": cur})
    return np.asarray(jnp.concatenate(out, axis=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.frontend == "audio_codebooks":
        raise SystemExit("use the musicgen example for codebook decoding")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.new_tokens,
                   temperature=args.temperature)
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"[serve] arch={cfg.name} generated {out.shape} "
          f"({total_new / dt:.1f} tok/s incl. prefill+compile)")
    print(out[:, args.prompt_len:][:2])


if __name__ == "__main__":
    main()
