import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (into benchmarks/results/dryrun/*.json):

  * the **full compile** (scan-over-layers, remat, real layer count) on the
    16x16 single-pod mesh AND the 2x16x16 multi-pod mesh — proving the
    sharding config is coherent (memory_analysis = fits; collective ops
    resolve);
  * **costing lowers**: the same program with every scan unrolled at
    n_layers = period and 2*period (period = attn_every for hybrids, else 1),
    because XLA's cost analysis counts a while body once; per-layer slopes
    b = (c2-c1)/period and intercept a = c1 - period*b extrapolate exact
    FLOPs / bytes / collective-bytes to the real depth:  total = a + L*b.
  * collective bytes parsed from post-SPMD ``compiled.as_text()``
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand shapes, summed per op kind).

Usage:
  python -m repro.launch.dryrun [--arch yi-9b] [--shape train_4k]
      [--mesh single|multi|both] [--out DIR] [--skip-costing]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, per kind."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# --------------------------------------------------------------------------
def auto_microbatches(cfg, global_batch: int, dp_size: int) -> int:
    """Baseline microbatch policy: local microbatch ~2 sequences for wide
    models (d_model >= 4096), ~8 otherwise — fits 16 GiB/chip at 4k train.
    (The §Perf hillclimb tunes this per cell.)"""
    b_local = max(global_batch // dp_size, 1)
    target = 4 if cfg.d_model < 4096 else 2
    if cfg.d_model >= 5120 or (cfg.is_ssm and cfg.d_model >= 4096):
        target = 1   # widest models / mamba chunk states; EXPERIMENTS.md §Dry-run
    mb = max(b_local // target, 1)
    while b_local % mb:
        mb -= 1
    return max(mb, 1)


def build_cell(arch: str, shape: str, *, n_layers_override=None,
               unroll=False, remat=None, dp_size: int = 16,
               microbatches: int | None = None):
    """Returns (step_fn, arg_shapes, in_specs_fn) for one cell."""
    from repro.configs import get_arch, get_shape, input_specs
    from repro.models import decode_step, init_cache, init_params, prefill
    from repro.train import AdamWConfig, adamw_init, make_train_step

    cfg = get_arch(arch)
    if n_layers_override:
        cfg = dataclasses.replace(cfg, n_layers=n_layers_override)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if unroll:
        # fewer, larger chunk bodies for the unrolled costing lowers (the
        # chunked recurrences are exact for any chunk size; memory analysis
        # comes from the real compile, not these)
        cfg = dataclasses.replace(cfg, ssm_chunk=1024)
    sh = get_shape(shape)
    batch_shapes = input_specs(cfg, shape)
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))

    if sh["kind"] == "train":
        ocfg = AdamWConfig()
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        mb = microbatches or auto_microbatches(cfg, sh["global_batch"], dp_size)
        step = make_train_step(cfg, ocfg, unroll=unroll, num_microbatches=mb)
        args = (params_shapes, opt_shapes, batch_shapes)
        kind = "train"
    elif sh["kind"] == "prefill":
        def step(params, batch):
            return prefill(cfg, params, batch, unroll=unroll)
        args = (params_shapes, batch_shapes)
        kind = "prefill"
    else:
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, sh["global_batch"], sh["seq_len"]))

        def step(params, cache, batch):
            return decode_step(cfg, params, cache, batch, unroll=unroll)
        args = (params_shapes, cache_shapes, batch_shapes)
        kind = "decode"
    return cfg, step, args, kind


def shardings_for(mesh, args, kind, expert_2d=False, layout="tp"):
    from repro.sharding import (batch_specs, cache_specs, named, opt_specs,
                                param_specs)
    if kind == "train":
        params_s, opt_s, batch_s = args
        return (named(mesh, param_specs(params_s, mesh, expert_2d=expert_2d,
                                        layout=layout)),
                named(mesh, opt_specs(params_s, mesh, expert_2d=expert_2d,
                                      layout=layout)),
                named(mesh, batch_specs(batch_s, mesh,
                                        include_model=(layout == "dp"))))
    if kind == "prefill":
        params_s, batch_s = args
        return (named(mesh, param_specs(params_s, mesh, serve=True)),
                named(mesh, batch_specs(batch_s, mesh)))
    params_s, cache_s, batch_s = args
    return (named(mesh, param_specs(params_s, mesh, serve=True)),
            named(mesh, cache_specs(cache_s, mesh)),
            named(mesh, batch_specs(batch_s, mesh)))


def lower_cell(mesh, arch, shape, *, n_layers_override=None, unroll=False,
               remat=None, microbatches=None, expert_2d=False, layout="tp"):
    dp = int(np.prod([s for s, a in zip(mesh.devices.shape, mesh.axis_names)
                      if a in ("pod", "data")]))
    if layout == "dp":
        dp *= int(np.prod([s for s, a in zip(mesh.devices.shape, mesh.axis_names)
                           if a == "model"]))
    # costing lowers use a single microbatch (identical per-token math; the
    # scan-counting problem would otherwise hide mb-1 of the accumulation)
    if unroll and microbatches is None:
        microbatches = 1
    from repro.sharding import compat_set_mesh
    cfg, step, args, kind = build_cell(arch, shape,
                                       n_layers_override=n_layers_override,
                                       unroll=unroll, remat=remat,
                                       dp_size=dp, microbatches=microbatches)
    in_sh = shardings_for(mesh, args, kind, expert_2d=expert_2d, layout=layout)
    # production aliasing: train updates (params, opt) in place; decode
    # updates the cache in place
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[kind]
    with compat_set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return cfg, compiled


def analyze(compiled) -> dict:
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_estimate": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
    }


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: pathlib.Path,
             costing: bool = True, variant: str | None = None,
             **lower_kw) -> dict:
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "mesh_shape": list(mesh.devices.shape),
                 "variant": variant or "baseline", "overrides": repr(lower_kw)}
    t0 = time.time()

    # 1) the real compile (scan, remat, full depth): memory + schedule proof
    cfg, compiled = lower_cell(mesh, arch, shape, **lower_kw)
    full = analyze(compiled)
    rec["full"] = full
    rec["compile_seconds"] = time.time() - t0

    # 2) costing lowers (single-pod only: per-chip roofline; the multi-pod
    #    pass proves the pod axis shards)
    if costing:
        period = cfg.attn_every or 1
        t1 = time.time()
        _, c1 = lower_cell(mesh, arch, shape, n_layers_override=period,
                           unroll=True, **lower_kw)
        a1 = analyze(c1)
        _, c2 = lower_cell(mesh, arch, shape, n_layers_override=2 * period,
                           unroll=True, **lower_kw)
        a2 = analyze(c2)
        L = cfg.n_layers

        def extrapolate(v1, v2):
            b = (v2 - v1) / period
            a = v1 - period * b
            return a + L * b

        rec["costing"] = {
            "flops": extrapolate(a1["flops"], a2["flops"]),
            "bytes": extrapolate(a1["bytes"], a2["bytes"]),
            "collective_bytes": extrapolate(a1["collectives"]["total"],
                                            a2["collectives"]["total"]),
            "collectives_by_kind": {
                k: extrapolate(a1["collectives"].get(k, 0.0),
                               a2["collectives"].get(k, 0.0))
                for k in set(a1["collectives"]) | set(a2["collectives"])
                if k != "total"},
            "period": period,
            "costing_seconds": time.time() - t1,
        }

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{variant}" if variant else ""
    path = out_dir / f"{arch}__{shape}__{mesh_kind}{tag}.json"
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-costing", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="tag for §Perf experiments (suffixes the JSON name)")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp", "fsdp"])
    ap.add_argument("--expert-2d", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    from repro.configs import runnable_cells
    out_dir = pathlib.Path(args.out)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    cells = [(a, s) for a, s, ok in runnable_cells() if ok
             and (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]
    skipped = [(a, s) for a, s, ok in runnable_cells() if not ok
               and (args.arch is None or a == args.arch)
               and (args.shape is None or s == args.shape)]
    for a, s in skipped:
        print(f"SKIP {a} x {s} (full attention at 500k — see DESIGN.md §5)")

    failures = []
    for a, s in cells:
        for mk in meshes:
            tag = f"{a} x {s} x {mk}"
            if args.skip_existing and (out_dir / f"{a}__{s}__{mk}.json").exists():
                print(f"HAVE {tag}")
                continue
            try:
                t0 = time.time()
                # costing only needed once (per-chip terms identical across pods)
                rec = run_cell(a, s, mk, out_dir,
                               costing=(not args.skip_costing and mk == "single"),
                               variant=args.variant, layout=args.layout,
                               expert_2d=args.expert_2d,
                               microbatches=args.microbatches)
                mem = rec["full"]["memory"]["peak_hbm_estimate"] / 2**30
                print(f"OK   {tag}: peak/dev ~{mem:.2f} GiB, "
                      f"colls {rec['full']['collectives']['total']/2**20:.1f} MiB, "
                      f"{time.time()-t0:.0f}s")
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}")
                traceback.print_exc()
    print(f"\n{len(cells)*len(meshes)-len(failures)} ok, {len(failures)} failed,"
          f" {len(skipped)} skipped")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
