# Launch layer. NOTE: dryrun must be imported as a MAIN MODULE
# (python -m repro.launch.dryrun) so its XLA_FLAGS line runs before jax
# initializes; do not import it from here.
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
