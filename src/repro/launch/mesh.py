"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run forces 512 host devices before
any jax import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "compat_make_mesh"]


def compat_make_mesh(shape, axes, devices):
    """jax.make_mesh across jax versions: ``axis_types`` (and the AxisType
    enum itself) only exist on newer jax; older versions get the default
    (auto) axis semantics, which is what we ask for anyway."""
    try:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single-pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {shape} mesh, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return compat_make_mesh(shape, axes, devices)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU runs)."""
    n = data * model
    return compat_make_mesh((data, model), ("data", "model"),
                            jax.devices()[:n])
