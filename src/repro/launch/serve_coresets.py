"""Coreset serving launcher: v1 HTTP front over the CoresetEngine.

  python -m repro.launch.serve_coresets --port 8787            # serve
  python -m repro.launch.serve_coresets --smoke                # self-check

``--smoke`` boots the server on an ephemeral port and drives it exclusively
through the typed SDK (``repro.client.CoresetClient`` — both the binary and
JSON encodings) with >= 4 concurrent client threads (register + build +
tree-loss + forest-fit + streamed ingest), then asserts:

  * at least one *dominance* cache hit was served (a (k', eps') coreset
    answered a (k <= k', eps >= eps') request without a rebuild);
  * the streamed-ingest coreset's Algorithm-5 loss agrees with a one-shot
    ``signal_coreset`` build within the composed eps bound
    (|L_stream - L_oneshot| <= (eps_eff + eps) * true_loss);
  * a fused ``/v1/query/loss:batch`` of T segmentations matches T
    sequential ``/v1/query/loss`` answers while consuming ONE engine
    scoring call instead of T;
  * legacy unversioned routes still answer, with the ``Deprecation``
    header and a ``Link: </v1/...>; rel="successor-version"`` pointer.

Exit code 0 iff all checks pass.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import urllib.request

import numpy as np

from repro.client import CoresetClient
from repro.service import CoresetEngine, ServiceMetrics, make_server, serve_forever_in_thread

__all__ = ["main", "run_smoke"]


def run_smoke(*, clients: int = 4, rounds: int = 6, verbose: bool = True) -> int:
    from repro.core import fitting_loss, random_tree_segmentation, signal_coreset, true_loss
    from repro.data.signals import piecewise_signal

    metrics = ServiceMetrics()
    engine = CoresetEngine(workers=4, metrics=metrics)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    n, m, k_max, eps_tight = 96, 64, 8, 0.2
    y = piecewise_signal(n, m, k_max, noise=0.15, seed=7)
    setup = CoresetClient(base, encoding="binary")
    setup.register_signal("dense", values=y)
    # anchor build: the (k_max, eps_tight) coreset every later query dominates
    setup.build("dense", k_max, eps_tight)

    errors: list[str] = []
    rng_global = np.random.default_rng(123)
    band_rows = 16
    stream_eps = 0.25

    def query_client(cid: int) -> None:
        # odd clients speak JSON, even speak binary: both negotiated paths
        # are exercised under concurrency
        cl = CoresetClient(base, encoding="json" if cid % 2 else "binary")
        rng = np.random.default_rng(1000 + cid)
        try:
            for _ in range(rounds):
                kq = int(rng.integers(3, k_max + 1))
                q = random_tree_segmentation(n, m, kq, rng)
                r = cl.query_loss("dense", q.rects, q.labels, eps=0.3)
                tl = true_loss(y, q.rects, q.labels)
                if tl > 1e-9 and abs(r.loss - tl) / tl > 0.3 + 1e-6:
                    errors.append(f"client {cid}: rel err "
                                  f"{abs(r.loss - tl) / tl:.3f} > eps")
            cl.fit("dense", k_max, eps_tight, n_estimators=3,
                   predict=[[1, 1], [n - 2, m - 2]])
        except Exception as exc:  # noqa: BLE001
            errors.append(f"client {cid}: {type(exc).__name__}: {exc}")

    def ingest_client() -> None:
        cl = CoresetClient(base, encoding="binary")
        try:
            for i in range(0, n, band_rows):
                cl.ingest("stream", band=y[i:i + band_rows])
            cl.build("stream", k_max, stream_eps)
        except Exception as exc:  # noqa: BLE001
            errors.append(f"ingest: {type(exc).__name__}: {exc}")

    threads = [threading.Thread(target=query_client, args=(cid,))
               for cid in range(max(clients - 1, 3))]
    threads.append(threading.Thread(target=ingest_client))
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # ---- streamed-ingest consistency vs one-shot build (composed eps bound)
    q = random_tree_segmentation(n, m, 6, rng_global)
    r_stream = setup.query_loss("stream", q.rects, q.labels,
                                eps=stream_eps, k=k_max)
    cs_one = signal_coreset(y, k_max, stream_eps)
    l_one = fitting_loss(cs_one, q.rects, q.labels)
    tl = true_loss(y, q.rects, q.labels)
    composed = r_stream.eps_eff + stream_eps
    gap = abs(r_stream.loss - l_one) / max(tl, 1e-12)
    if gap > composed:
        errors.append(f"streamed vs one-shot gap {gap:.3f} > composed "
                      f"bound {composed:.3f}")

    # ---- fused batch query: one scoring call, answers match sequential
    T = 8
    segs = [random_tree_segmentation(n, m, 5, rng_global) for _ in range(T)]
    batch_rects = np.stack([s.rects for s in segs])
    batch_labels = np.stack([s.labels for s in segs])
    calls_before = metrics.get("loss_scoring_calls")
    rb = setup.query_loss_batch("dense", batch_rects, batch_labels, eps=0.3)
    fused_calls = metrics.get("loss_scoring_calls") - calls_before
    if fused_calls != 1:
        errors.append(f"batch query consumed {fused_calls} scoring calls, "
                      "expected 1")
    seq = [setup.query_loss("dense", s.rects, s.labels, eps=0.3).loss
           for s in segs]
    if not np.allclose(rb.losses, seq, rtol=1e-4):
        errors.append("batch losses diverge from sequential /v1/query/loss")

    # ---- legacy shim still answers, with the Deprecation header
    req = urllib.request.Request(
        base + "/healthz")
    with urllib.request.urlopen(req, timeout=30) as resp:
        legacy_health = json.loads(resp.read())
        if resp.headers.get("Deprecation") != "true":
            errors.append("legacy /healthz missing Deprecation header")
        if "/v1/healthz" not in (resp.headers.get("Link") or ""):
            errors.append("legacy /healthz missing successor-version Link")

    health = setup.healthz()
    dominated = metrics.get("cache_hit_dominated")
    if dominated < 1:
        errors.append("no dominance cache hit was served")
    if health.get("status") != "ok" or legacy_health.get("status") != "ok":
        errors.append(f"healthz: {health} / legacy {legacy_health}")

    srv.shutdown()
    engine.close()

    if verbose:
        snap = metrics.snapshot()
        print(f"[smoke] clients={len(threads)} http_200="
              f"{snap['counters'].get('http_200', 0)} "
              f"builds={snap['counters'].get('builds_completed', 0)} "
              f"exact_hits={snap['counters'].get('cache_hit_exact', 0)} "
              f"dominance_hits={dominated} "
              f"batch_scoring_calls={fused_calls} "
              f"stream_gap={gap:.4f} (bound {composed:.3f})")
        for e in errors:
            print(f"[smoke] FAIL: {e}")
        print(f"[smoke] {'PASS' if not errors else 'FAIL'}")
    return 0 if not errors else 1


def _runtime_hygiene(verbose: bool = True) -> None:
    """Best-effort serving-process hygiene (the process-level half —
    tcmalloc preload, TF log silencing — lives in ``scripts/run.sh``):

      * persistent XLA compilation cache: jit recompiles of the same
        kernels across restarts are pure waste on a serving box
        (``JAX_COMPILATION_CACHE_DIR`` overrides the location);
      * pre-load the kernel autotune cache so the first dispatch does not
        pay the disk read + fingerprint check mid-request.

    Every step degrades to a no-op on failure: hygiene must never stop a
    server from booting.
    """
    import os
    try:
        import jax
        cache_dir = (os.environ.get("JAX_COMPILATION_CACHE_DIR")
                     or os.path.expanduser("~/.cache/repro/jax_cache"))
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        try:    # not present on every jax version shipped in the image
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              0.5)
        except Exception:  # noqa: BLE001
            pass
        if verbose:
            print(f"[serve_coresets] XLA compilation cache: {cache_dir}",
                  flush=True)
    except Exception as exc:  # noqa: BLE001
        print(f"[serve_coresets] XLA compilation cache unavailable: "
              f"{type(exc).__name__}: {exc}", flush=True)
    try:
        from repro.ops import autotune
        snap = autotune.snapshot()
        if verbose:
            print(f"[serve_coresets] autotune cache: {snap['entries']} "
                  f"entries from {snap['cache_path']} "
                  f"(loaded={snap['cache_loaded']}, "
                  f"fingerprint {snap['fingerprint']}, "
                  f"precision={snap['precision_mode']})", flush=True)
    except Exception as exc:  # noqa: BLE001
        print(f"[serve_coresets] autotune cache unavailable: "
              f"{type(exc).__name__}: {exc}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--role", choices=("single", "worker", "coordinator"),
                    default="single",
                    help="single = the classic one-process engine; worker = "
                         "a ShardWorker band server (cluster data plane); "
                         "coordinator = ClusterEngine scattering dense "
                         "builds to --peers behind the full v1 API")
    ap.add_argument("--peers", default="",
                    help="coordinator only: comma-separated worker base "
                         "URLs, e.g. http://10.0.0.2:9001,http://10.0.0.3:9001")
    ap.add_argument("--worker-id", default=None,
                    help="worker only: stable id reported in acks/metrics "
                         "(default host:port)")
    ap.add_argument("--rpc-timeout", type=float, default=30.0,
                    help="coordinator only: per-band-RPC deadline seconds")
    ap.add_argument("--reprobe-s", type=float, default=1.0,
                    help="coordinator only: cooldown before re-probing a "
                         "down worker")
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--num-bands", type=int, default=4)
    ap.add_argument("--query-window-ms", type=float, default=2.0,
                    help="cross-request loss-query batching window")
    ap.add_argument("--query-max-fuse", type=int, default=16,
                    help="flush a query bucket early once this many trees "
                         "queue (the batched kernel's T tile)")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable cross-request query coalescing engine-wide")
    ap.add_argument("--no-tracing", action="store_true",
                    help="disable request tracing (spans, /v1/trace/*)")
    ap.add_argument("--access-log", metavar="PATH", default=None,
                    help="JSON-lines access log: one object per request "
                         "(method, path, status, duration_ms, trace_id); "
                         "'-' = stderr.  Off by default")
    ap.add_argument("--slow-ms", type=float, default=None,
                    help="with --access-log, only log requests taking at "
                         "least this many milliseconds (slow-request log)")
    ap.add_argument("--admission", action="store_true",
                    help="enable front-door admission control: 503 + "
                         "Retry-After for work predicted to miss its "
                         "deadline_ms, plus per-tenant weighted fair-share "
                         "rate/in-flight caps (X-Coreset-Tenant header)")
    ap.add_argument("--admission-rate", type=float, default=None,
                    metavar="RPS",
                    help="total admitted requests/second, split across "
                         "tenants by weight (default: unlimited)")
    ap.add_argument("--admission-burst-s", type=float, default=1.0,
                    help="token-bucket depth in seconds of a tenant's rate "
                         "share")
    ap.add_argument("--admission-max-inflight", type=int, default=None,
                    help="total in-flight requests, split across tenants by "
                         "weight (default: unlimited)")
    ap.add_argument("--admission-tenants", default="",
                    metavar="NAME=W,...",
                    help="tenant weights, e.g. 'gold=4,silver=2' — unknown "
                         "tenants join at --admission-default-weight")
    ap.add_argument("--admission-default-weight", type=float, default=1.0)
    ap.add_argument("--no-deadline-guard", action="store_true",
                    help="with --admission, keep fair-share caps but never "
                         "reject on predicted deadline misses")
    ap.add_argument("--no-runtime-hygiene", action="store_true",
                    help="skip startup hygiene (persistent XLA compilation "
                         "cache, autotune-cache preload)")
    ap.add_argument("--smoke", action="store_true",
                    help="self-check with concurrent SDK clients, then exit")
    args = ap.parse_args()

    if not args.no_runtime_hygiene:
        _runtime_hygiene(verbose=not args.smoke)

    if args.smoke:
        sys.exit(run_smoke())

    if args.no_tracing:
        from repro import obs
        obs.set_enabled(False)

    admission = None
    if args.admission:
        from repro.service.admission import AdmissionConfig, AdmissionController
        admission = AdmissionController(AdmissionConfig(
            tenants=AdmissionConfig.parse_tenants(args.admission_tenants),
            default_weight=args.admission_default_weight,
            rate_rps=args.admission_rate,
            burst_s=args.admission_burst_s,
            max_inflight=args.admission_max_inflight,
            parallelism=args.workers,
            deadline_guard=not args.no_deadline_guard))
    elif (args.admission_rate is not None
          or args.admission_max_inflight is not None
          or args.admission_tenants):
        ap.error("--admission-* options require --admission")

    access_fp = None
    if args.access_log is not None:
        access_fp = (sys.stderr if args.access_log == "-"
                     else open(args.access_log, "a", buffering=1))
    elif args.slow_ms is not None:
        ap.error("--slow-ms requires --access-log")

    if args.role == "worker":
        from repro.cluster import ShardWorker, make_worker_server
        worker = ShardWorker(worker_id=args.worker_id
                             or f"{args.host}:{args.port}")
        srv = make_worker_server(worker, host=args.host, port=args.port)
        print(f"[serve_coresets] worker {worker.worker_id} listening on "
              f"http://{args.host}:{srv.server_address[1]}  "
              f"(POST /v1/worker/band:assign band:delta band:build; "
              f"GET /v1/healthz /v1/metrics)", flush=True)
        try:
            srv.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            srv.shutdown()
        return

    if args.role == "coordinator":
        from repro.cluster import ClusterEngine
        peers = [p.strip() for p in args.peers.split(",") if p.strip()]
        if not peers:
            ap.error("--role coordinator requires --peers")
        engine = ClusterEngine(peers, rpc_timeout=args.rpc_timeout,
                               reprobe_s=args.reprobe_s,
                               cache_bytes=args.cache_mb << 20,
                               workers=args.workers,
                               query_window=args.query_window_ms / 1e3,
                               query_max_fuse=args.query_max_fuse,
                               coalesce=not args.no_coalesce,
                               admission=admission)
        up = sum("error" not in h for h in engine.probe_workers().values())
        print(f"[serve_coresets] coordinator: {up}/{len(peers)} workers up",
              flush=True)
    else:
        engine = CoresetEngine(cache_bytes=args.cache_mb << 20,
                               workers=args.workers,
                               num_bands=args.num_bands,
                               query_window=args.query_window_ms / 1e3,
                               query_max_fuse=args.query_max_fuse,
                               coalesce=not args.no_coalesce,
                               admission=admission)
    srv = make_server(engine, host=args.host, port=args.port,
                      access_log=access_fp, slow_ms=args.slow_ms)
    print(f"[serve_coresets] listening on http://{args.host}:"
          f"{srv.server_address[1]}  (v1: POST /v1/signals /v1/ingest "
          f"/v1/build /v1/query/loss /v1/query/loss:batch /v1/query/fit "
          f"/v1/query/compress; GET /v1/healthz /v1/stats /v1/metrics "
          f"/v1/traces:recent /v1/trace/{{id}}; "
          f"legacy unversioned routes deprecated)")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.shutdown()
        engine.close()
        if access_fp is not None and access_fp is not sys.stderr:
            access_fp.close()


if __name__ == "__main__":
    main()
