"""Coreset serving launcher: HTTP front over the CoresetEngine.

  python -m repro.launch.serve_coresets --port 8787            # serve
  python -m repro.launch.serve_coresets --smoke                # self-check

``--smoke`` boots the server on an ephemeral port, drives it with >= 4
concurrent HTTP client threads (register + build + tree-loss + forest-fit +
streamed ingest), then asserts the acceptance properties:

  * at least one *dominance* cache hit was served (a (k', eps') coreset
    answered a (k <= k', eps >= eps') request without a rebuild);
  * the streamed-ingest coreset's Algorithm-5 loss agrees with a one-shot
    ``signal_coreset`` build within the composed eps bound
    (|L_stream - L_oneshot| <= (eps_eff + eps) * true_loss).

Exit code 0 iff all checks pass.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import urllib.request

import numpy as np

from repro.service import CoresetEngine, ServiceMetrics, make_server, serve_forever_in_thread

__all__ = ["main", "run_smoke"]


def _post(base: str, path: str, payload: dict) -> dict:
    req = urllib.request.Request(
        base + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    with urllib.request.urlopen(req, timeout=120) as resp:
        return json.loads(resp.read())


def _get(base: str, path: str):
    with urllib.request.urlopen(base + path, timeout=30) as resp:
        body = resp.read()
    try:
        return json.loads(body)
    except json.JSONDecodeError:
        return body.decode()


def run_smoke(*, clients: int = 4, rounds: int = 6, verbose: bool = True) -> int:
    from repro.core import fitting_loss, random_tree_segmentation, signal_coreset, true_loss
    from repro.core.segmentation import Segmentation  # noqa: F401  (rects shape doc)
    from repro.data.signals import piecewise_signal

    metrics = ServiceMetrics()
    engine = CoresetEngine(workers=4, metrics=metrics)
    srv = make_server(engine)
    serve_forever_in_thread(srv)
    base = f"http://127.0.0.1:{srv.server_address[1]}"

    n, m, k_max, eps_tight = 96, 64, 8, 0.2
    y = piecewise_signal(n, m, k_max, noise=0.15, seed=7)
    _post(base, "/signals", {"name": "dense", "values": y.tolist()})
    # anchor build: the (k_max, eps_tight) coreset every later query dominates
    _post(base, "/build", {"name": "dense", "k": k_max, "eps": eps_tight})

    errors: list[str] = []
    rng_global = np.random.default_rng(123)
    band_rows = 16
    stream_eps = 0.25

    def query_client(cid: int) -> None:
        rng = np.random.default_rng(1000 + cid)
        try:
            for _ in range(rounds):
                kq = int(rng.integers(3, k_max + 1))
                q = random_tree_segmentation(n, m, kq, rng)
                r = _post(base, "/query/loss", {
                    "name": "dense", "rects": q.rects.tolist(),
                    "labels": q.labels.tolist(), "eps": 0.3})
                tl = true_loss(y, q.rects, q.labels)
                if tl > 1e-9 and abs(r["loss"] - tl) / tl > 0.3 + 1e-6:
                    errors.append(f"client {cid}: rel err "
                                  f"{abs(r['loss'] - tl) / tl:.3f} > eps")
            _post(base, "/query/fit", {"name": "dense", "k": k_max,
                                       "eps": eps_tight, "n_estimators": 3,
                                       "predict": [[1, 1], [n - 2, m - 2]]})
        except Exception as exc:  # noqa: BLE001
            errors.append(f"client {cid}: {type(exc).__name__}: {exc}")

    def ingest_client() -> None:
        try:
            for i in range(0, n, band_rows):
                _post(base, "/ingest", {"name": "stream",
                                        "band": y[i:i + band_rows].tolist()})
            _post(base, "/build", {"name": "stream", "k": k_max,
                                   "eps": stream_eps})
        except Exception as exc:  # noqa: BLE001
            errors.append(f"ingest: {type(exc).__name__}: {exc}")

    threads = [threading.Thread(target=query_client, args=(cid,))
               for cid in range(max(clients - 1, 3))]
    threads.append(threading.Thread(target=ingest_client))
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # ---- streamed-ingest consistency vs one-shot build (composed eps bound)
    q = random_tree_segmentation(n, m, 6, rng_global)
    r_stream = _post(base, "/query/loss", {
        "name": "stream", "rects": q.rects.tolist(),
        "labels": q.labels.tolist(), "eps": stream_eps, "k": k_max})
    cs_one = signal_coreset(y, k_max, stream_eps)
    l_one = fitting_loss(cs_one, q.rects, q.labels)
    tl = true_loss(y, q.rects, q.labels)
    composed = r_stream["eps_eff"] + stream_eps
    gap = abs(r_stream["loss"] - l_one) / max(tl, 1e-12)
    if gap > composed:
        errors.append(f"streamed vs one-shot gap {gap:.3f} > composed "
                      f"bound {composed:.3f}")

    health = _get(base, "/healthz")
    dominated = metrics.get("cache_hit_dominated")
    if dominated < 1:
        errors.append("no dominance cache hit was served")
    if health.get("status") != "ok":
        errors.append(f"healthz: {health}")

    srv.shutdown()
    engine.close()

    if verbose:
        snap = metrics.snapshot()
        print(f"[smoke] clients={len(threads)} http_200="
              f"{snap['counters'].get('http_200', 0)} "
              f"builds={snap['counters'].get('builds_completed', 0)} "
              f"exact_hits={snap['counters'].get('cache_hit_exact', 0)} "
              f"dominance_hits={dominated} "
              f"stream_gap={gap:.4f} (bound {composed:.3f})")
        for e in errors:
            print(f"[smoke] FAIL: {e}")
        print(f"[smoke] {'PASS' if not errors else 'FAIL'}")
    return 0 if not errors else 1


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--num-bands", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="self-check with concurrent clients, then exit")
    args = ap.parse_args()

    if args.smoke:
        sys.exit(run_smoke())

    engine = CoresetEngine(cache_bytes=args.cache_mb << 20,
                           workers=args.workers, num_bands=args.num_bands)
    srv = make_server(engine, host=args.host, port=args.port)
    print(f"[serve_coresets] listening on http://{args.host}:"
          f"{srv.server_address[1]}  (POST /signals /ingest /build "
          f"/query/loss /query/fit /query/compress; GET /healthz /stats /metrics)")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.shutdown()
        engine.close()


if __name__ == "__main__":
    main()
