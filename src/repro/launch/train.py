"""End-to-end training driver.

Wires every substrate together: config -> mesh -> sharded params/optimizer ->
step-indexed data -> jitted train step -> async checkpoints -> crash-only
supervision.  Runs the full-size configs on a real TPU mesh and the reduced
configs on this CPU container (``--reduced``), e.g.:

  python -m repro.launch.train --arch qwen2-0.5b --reduced --steps 50
  python -m repro.launch.train --arch musicgen-medium --reduced --steps 100 \
      --d-model 512 --layers 8          # ~100M-param class driver
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.configs import get_arch, reduced_config
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import init_params
from repro.runtime.fault_tolerance import supervise
from repro.sharding import (compat_set_mesh, named,
                            opt_specs, param_specs)
from repro.train import AdamWConfig, adamw_init, make_train_step

__all__ = ["train_loop", "main"]


def train_loop(cfg, *, steps: int, batch: int, seq_len: int, mesh=None,
               ckpt_dir: str | None = None, save_every: int = 50,
               microbatches: int = 1, log_every: int = 10, seed: int = 0,
               resume: bool = True, fail_at: int | None = None) -> dict:
    """Returns final {"params", "opt", "step", "losses"}."""
    mesh = mesh or make_local_mesh(1, 1)
    ocfg = AdamWConfig(total_steps=steps)
    stream = TokenStream(cfg.vocab, batch, seq_len, seed=seed,
                         n_codebooks=cfg.n_codebooks)

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    pspec = named(mesh, param_specs(params, mesh))
    ospec = named(mesh, opt_specs(params, mesh))
    params = jax.tree.map(jax.device_put, params, pspec)
    opt = jax.tree.map(jax.device_put, opt, ospec)

    step_fn = make_train_step(cfg, ocfg, num_microbatches=microbatches)
    with compat_set_mesh(mesh):
        jitted = jax.jit(step_fn,
                         in_shardings=(pspec, ospec, None),
                         donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    state = {"params": params, "opt": opt, "step": 0}
    if mgr and resume:
        last = mgr.latest_step()
        if last is not None:
            state = mgr.restore(last, state, shardings=None)
            state["params"] = jax.tree.map(jax.device_put, state["params"], pspec)
            state["opt"] = jax.tree.map(jax.device_put, state["opt"], ospec)
            print(f"[train] resumed from step {last}")

    losses: list[float] = []
    t_last = time.time()
    injected = {"done": False}

    def run_step(step: int, state: dict) -> dict:
        if fail_at is not None and step == fail_at and not injected["done"]:
            injected["done"] = True   # fail once; replay must succeed
            raise RuntimeError("injected failure (test)")
        b = stream.batch_at(step)
        batch_dev = {k: jax.numpy.asarray(v) for k, v in b.items()}
        with compat_set_mesh(mesh):
            p, o, m = jitted(state["params"], state["opt"], batch_dev)
        loss = float(m["loss"])
        losses.append(loss)
        if step % log_every == 0:
            nonlocal t_last
            dt = time.time() - t_last
            t_last = time.time()
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} ({dt:.1f}s)")
        return {"params": p, "opt": o, "step": step}

    if mgr:
        state = supervise(run_step, state, steps=steps, ckpt_mgr=mgr,
                          save_every=save_every)
    else:
        for s in range(state["step"], steps):
            state = run_step(s, state)
            state["step"] = s + 1
    state["losses"] = losses
    return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        n_heads=max(args.d_model // 64, 4),
                        n_kv_heads=max(args.d_model // 128, 2),
                        d_ff=args.d_model * 3 if cfg.d_ff else 0)
        if args.layers:
            over["n_layers"] = args.layers
        if args.vocab:
            over["vocab"] = args.vocab
        cfg = reduced_config(cfg, **over)
    mesh = make_production_mesh() if args.production_mesh else make_local_mesh()
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")
    state = train_loop(cfg, steps=args.steps, batch=args.batch,
                       seq_len=args.seq, mesh=mesh, ckpt_dir=args.ckpt_dir,
                       save_every=args.save_every,
                       microbatches=args.microbatches, seed=args.seed)
    ls = state["losses"]
    if ls:
        k = max(len(ls) // 10, 1)
        print(f"[train] loss first-{k}-mean {np.mean(ls[:k]):.4f} -> "
              f"last-{k}-mean {np.mean(ls[-k:]):.4f}")


if __name__ == "__main__":
    main()
