from .signals import (blobs, circles, moons, piecewise_signal, rasterize,
                      sensor_matrix, smooth_field, zscore)
from .patches import patch_mask

__all__ = ["blobs", "circles", "moons", "piecewise_signal", "rasterize",
           "sensor_matrix", "smooth_field", "zscore", "patch_mask"]
