"""Synthetic signal generators (sklearn-free stand-ins for the paper's data).

The paper evaluates on (i) UCI Air Quality / Gesture Phase matrices
(instances x features, z-scored, treated as 2D signals) and (ii) the sklearn
blobs/moons/circles point sets rasterized as labeled signals (appendix A).
Neither UCI nor sklearn is reachable offline, so this module regenerates
statistically matched stand-ins:

  * ``sensor_matrix``     — UCI-like: correlated multivariate time series
                            (AR(1) rows, per-feature scales), z-scored;
  * ``piecewise_signal``  — ground-truth k-tree structure + noise;
  * ``smooth_field``      — separable low-frequency cosine field + noise;
  * ``blobs`` / ``moons`` / ``circles`` — re-implementations of the sklearn
    generators, plus ``rasterize`` to turn labeled points into a signal.
"""
from __future__ import annotations

import numpy as np

__all__ = ["sensor_matrix", "piecewise_signal", "smooth_field", "blobs",
           "moons", "circles", "rasterize", "zscore"]


def zscore(a: np.ndarray) -> np.ndarray:
    mu = a.mean(axis=0, keepdims=True)
    sd = a.std(axis=0, keepdims=True)
    return (a - mu) / np.maximum(sd, 1e-12)


def sensor_matrix(n: int = 9358, m: int = 15, rho: float = 0.995,
                  noise: float = 0.02, rank: int = 4, seed: int = 0) -> np.ndarray:
    """AR(1)-in-time, low-rank-across-features sensor matrix, z-scored per
    feature (the paper's Air Quality data: n=9358, m=15 — co-located gas
    sensors share slow drivers, so cross-feature structure is low rank and
    temporal drift is strong)."""
    rng = np.random.default_rng(seed)
    mix = rng.normal(size=(m, rank)) / np.sqrt(rank)
    x = np.empty((n, rank))
    state = rng.normal(size=rank)
    drive = rng.normal(size=(n, rank))
    for t in range(n):
        state = rho * state + np.sqrt(1 - rho * rho) * drive[t]
        x[t] = state
    x = x @ mix.T + noise * rng.normal(size=(n, m))
    return zscore(x)


def piecewise_signal(n: int, m: int, k: int, noise: float = 0.15,
                     scale: float = 2.0, seed: int = 0) -> np.ndarray:
    """Ground-truth k-tree structure + iid noise (the coreset-friendly regime)."""
    from repro.core.segmentation import random_tree_segmentation
    rng = np.random.default_rng(seed)
    seg = random_tree_segmentation(n, m, k, rng)
    base = np.zeros((n, m))
    for (r0, r1, c0, c1), lam in zip(seg.rects, seg.labels):
        base[r0:r1, c0:c1] = lam * scale
    return base + noise * rng.normal(size=(n, m))


def smooth_field(n: int, m: int, freq: int = 3, noise: float = 0.1,
                 seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ii = np.linspace(0, 1, n)[:, None]
    jj = np.linspace(0, 1, m)[None, :]
    out = np.zeros((n, m))
    for _ in range(freq):
        a, b = rng.uniform(0.5, 4, size=2)
        p, q = rng.uniform(0, 2 * np.pi, size=2)
        out += rng.normal() * np.cos(2 * np.pi * a * ii + p) * np.cos(2 * np.pi * b * jj + q)
    return out + noise * rng.normal(size=(n, m))


# ------------------------------------------------ sklearn-like point clouds
def blobs(n: int = 17000, centers=((0, 0), (4, 4), (-3, 5)),
          fractions=(0.5, 0.34, 0.16), std: float = 1.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    X, y = [], []
    for lab, (c, fr) in enumerate(zip(centers, fractions)):
        cnt = int(n * fr)
        X.append(rng.normal(size=(cnt, 2)) * std + np.asarray(c))
        y.append(np.full(cnt, lab, np.float64))
    return np.concatenate(X), np.concatenate(y)


def moons(n: int = 24000, noise: float = 0.08, seed: int = 0):
    rng = np.random.default_rng(seed)
    h = n // 2
    t = np.pi * rng.uniform(size=h)
    X1 = np.stack([np.cos(t), np.sin(t)], axis=1)
    X2 = np.stack([1 - np.cos(t), 0.5 - np.sin(t)], axis=1)
    X = np.concatenate([X1, X2]) + noise * rng.normal(size=(2 * h, 2))
    y = np.concatenate([np.zeros(h), np.ones(h)])
    return X, y


def circles(n: int = 26000, factor: float = 0.5, noise: float = 0.05, seed: int = 0):
    rng = np.random.default_rng(seed)
    h = n // 2
    t1 = 2 * np.pi * rng.uniform(size=h)
    t2 = 2 * np.pi * rng.uniform(size=n - h)
    X = np.concatenate([np.stack([np.cos(t1), np.sin(t1)], 1),
                        factor * np.stack([np.cos(t2), np.sin(t2)], 1)])
    X += noise * rng.normal(size=X.shape)
    y = np.concatenate([np.zeros(h), np.ones(n - h)])
    return X, y


def rasterize(X: np.ndarray, y: np.ndarray, n: int = 256, m: int = 256,
              fill: str = "nearest") -> np.ndarray:
    """Labeled points -> n x m signal: cell label = mean of its points;
    empty cells take the nearest filled value along rows then columns."""
    lo = X.min(axis=0)
    hi = X.max(axis=0)
    ij = np.clip(((X - lo) / np.maximum(hi - lo, 1e-12)
                  * [n - 1, m - 1]).astype(np.int64), 0, [n - 1, m - 1])
    s = np.zeros((n, m))
    c = np.zeros((n, m))
    np.add.at(s, (ij[:, 0], ij[:, 1]), y)
    np.add.at(c, (ij[:, 0], ij[:, 1]), 1.0)
    out = np.where(c > 0, s / np.maximum(c, 1), np.nan)
    if fill == "nearest":
        for axis in (1, 0):
            a = out if axis == 1 else out.T
            for row in a:
                ok = ~np.isnan(row)
                if ok.any() and not ok.all():
                    idx = np.arange(len(row))
                    row[~ok] = np.interp(idx[~ok], idx[ok], row[ok])
            out = a if axis == 1 else a.T
        out = np.nan_to_num(out, nan=float(np.nanmean(out)))
    return out
