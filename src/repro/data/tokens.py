"""Synthetic LM token pipeline: deterministic, host-sharded, restartable.

Real corpora are not reachable offline; the stream is a seeded Zipf mixture
with enough local structure (bigram chains) to give non-trivial loss curves.
The API mirrors a production pipeline: each host owns a disjoint shard
(``host_id``/``num_hosts``), batches are indexed by step so a restart at
step k reproduces the identical batch k (checkpoint/resume correctness is
tested on this property).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int                  # per-host batch
    seq_len: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    n_codebooks: int = 0        # musicgen-style (B, L, C) grids

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (restart-stable)."""
        rng = np.random.default_rng(
            (self.seed, self.host_id, self.num_hosts, int(step)))
        shape = (self.batch, self.seq_len + 1)
        if self.n_codebooks:
            shape = shape + (self.n_codebooks,)
        # Zipf body + bigram chain: token[t] depends on token[t-1] half the time
        z = rng.zipf(1.3, size=shape)
        toks = (z - 1) % self.vocab
        chain = rng.uniform(size=shape) < 0.5
        rolled = np.roll((toks * 31 + 7) % self.vocab, 1, axis=1)
        toks = np.where(chain, rolled, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
