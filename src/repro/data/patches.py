"""The §5 missing-value protocol: hold out random 5x5 patches as the test set."""
from __future__ import annotations

import numpy as np

__all__ = ["patch_mask"]


def patch_mask(n: int, m: int, test_fraction: float = 0.3, patch: int = 5,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Returns (train_mask, test_mask): test cells are random patch x patch
    squares covering ~test_fraction of the signal (paper §5: 30%, 5x5)."""
    rng = np.random.default_rng(seed)
    test = np.zeros((n, m), bool)
    target = int(test_fraction * n * m)
    guard = 0
    while test.sum() < target and guard < 100000:
        i = int(rng.integers(0, max(n - patch, 1)))
        j = int(rng.integers(0, max(m - patch, 1)))
        test[i:i + patch, j:j + patch] = True
        guard += 1
    return ~test, test
